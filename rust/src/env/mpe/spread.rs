//! MPE `simple_spread`: N agents must cover N landmarks — Fig 6 top-right.
//!
//! Shared reward: minus the sum over landmarks of the distance to the
//! closest agent, minus 1 per colliding agent pair (original scenario).
//! Continuous actions: 2-D acceleration in [-1, 1], scaled by the MPE
//! sensitivity factor.

use crate::core::{ActionSpec, Actions, EnvSpec, StepType, TimeStep};
use crate::env::mpe::core::{Entity, World};
use crate::env::MultiAgentEnv;
use crate::rng::Rng;

const ACCEL: f32 = 5.0; // MPE u_multiplier for spread-like scenarios
const EPISODE: usize = 25;

/// MPE simple_spread: `n` agents cover `n` landmarks, penalised for
/// collisions (continuous control, shared coverage reward).
pub struct Spread {
    spec: EnvSpec,
    rng: Rng,
    world: World,
    n: usize,
    t: usize,
}

impl Spread {
    /// An `n`-agent, `n`-landmark instance (the paper uses 3).
    pub fn new(n: usize, seed: u64) -> Self {
        Spread {
            spec: EnvSpec {
                name: "mpe_spread".into(),
                n_agents: n,
                obs_dim: 4 + 2 * n + 2 * (n - 1),
                action: ActionSpec::Continuous { dim: 2 },
                state_dim: n * (4 + 2 * n + 2 * (n - 1)),
                episode_limit: EPISODE,
            },
            rng: Rng::new(seed),
            world: World::default(),
            n,
            t: 0,
        }
    }

    fn observe(&self) -> Vec<Vec<f32>> {
        (0..self.n)
            .map(|i| {
                let me = &self.world.agents[i];
                let mut o = Vec::with_capacity(self.spec.obs_dim);
                o.extend_from_slice(&me.vel);
                o.extend_from_slice(&me.pos);
                for lm in &self.world.landmarks {
                    o.push(lm.pos[0] - me.pos[0]);
                    o.push(lm.pos[1] - me.pos[1]);
                }
                for (j, other) in self.world.agents.iter().enumerate() {
                    if j != i {
                        o.push(other.pos[0] - me.pos[0]);
                        o.push(other.pos[1] - me.pos[1]);
                    }
                }
                o
            })
            .collect()
    }

    fn reward(&self) -> f32 {
        let mut r = 0.0;
        for lm in &self.world.landmarks {
            let min_d = self
                .world
                .agents
                .iter()
                .map(|a| a.dist(lm))
                .fold(f32::INFINITY, f32::min);
            r -= min_d;
        }
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if self.world.agents[i].overlaps(&self.world.agents[j]) {
                    r -= 1.0;
                }
            }
        }
        r
    }

    fn timestep(&self, st: StepType, reward: f32) -> TimeStep {
        let observations = self.observe();
        let state = observations.concat();
        TimeStep {
            step_type: st,
            observations,
            rewards: vec![reward; self.n],
            discount: 1.0, // spread truncates (time limit), never terminates
            state,
            legal_actions: None,
        }
    }
}

impl MultiAgentEnv for Spread {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        self.t = 0;
        self.world = World::default();
        for _ in 0..self.n {
            let mut a = Entity::new(0.15, true, true);
            a.pos = [self.rng.range_f32(-1.0, 1.0), self.rng.range_f32(-1.0, 1.0)];
            self.world.agents.push(a);
        }
        for _ in 0..self.n {
            let mut l = Entity::new(0.05, false, false);
            l.pos = [self.rng.range_f32(-1.0, 1.0), self.rng.range_f32(-1.0, 1.0)];
            self.world.landmarks.push(l);
        }
        self.timestep(StepType::First, 0.0)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let acts = actions.as_continuous();
        self.t += 1;
        let forces: Vec<[f32; 2]> = acts
            .iter()
            .map(|a| [a[0].clamp(-1.0, 1.0) * ACCEL, a[1].clamp(-1.0, 1.0) * ACCEL])
            .collect();
        self.world.step(&forces);
        let r = self.reward();
        let st = if self.t >= EPISODE { StepType::Last } else { StepType::Mid };
        self.timestep(st, r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_matches_preset() {
        let env = Spread::new(3, 0);
        assert_eq!(env.spec().obs_dim, 14);
        assert_eq!(env.spec().state_dim, 42);
    }

    #[test]
    fn reward_improves_when_agents_reach_landmarks() {
        let mut env = Spread::new(3, 1);
        env.reset();
        let r_far = env.reward();
        // teleport agents onto landmarks
        for i in 0..3 {
            env.world.agents[i].pos = env.world.landmarks[i].pos;
        }
        let r_on = env.reward();
        assert!(r_on > r_far, "{r_on} !> {r_far}");
        assert!(r_on > -0.5, "covering all landmarks ~0 distance cost");
    }

    #[test]
    fn collision_penalty_applies() {
        let mut env = Spread::new(3, 2);
        env.reset();
        for a in &mut env.world.agents {
            a.pos = [0.0, 0.0];
        }
        let r = env.reward();
        // 3 overlapping pairs -> at least -3 from collisions
        let dist_part: f32 = env
            .world
            .landmarks
            .iter()
            .map(|lm| {
                env.world.agents.iter().map(|a| a.dist(lm)).fold(f32::INFINITY, f32::min)
            })
            .sum();
        assert!((r + dist_part + 3.0).abs() < 1e-5);
    }

    #[test]
    fn episode_runs_25_steps() {
        let mut env = Spread::new(3, 3);
        let mut rng = Rng::new(4);
        let (_, steps) = crate::env::random_episode(&mut env, &mut rng);
        assert_eq!(steps, 25);
    }
}
