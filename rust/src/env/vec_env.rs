//! Batched environment execution: the executor-side half of the
//! vectorized hot path (DESIGN.md §6).
//!
//! A [`VecEnv`] owns `B = num_envs_per_executor` instances of any
//! [`MultiAgentEnv`] and steps them together. Two stepping APIs share
//! the auto-reset protocol:
//!
//! * the legacy [`VecEnv::step`] returns a [`VecStep`] of owned
//!   [`TimeStep`]s (allocating; kept for tests and the serial path);
//! * the hot path [`VecEnv::step_into`] writes every instance's
//!   observations / rewards / state / legal mask **in place** into a
//!   reusable struct-of-arrays [`VecStepBuf`], driven by a flat
//!   [`ActionBuf`] — zero steady-state heap allocations when the
//!   environments implement the SoA write hooks
//!   ([`MultiAgentEnv::writes_soa`]); other environments are bridged
//!   through the timestep API transparently.
//!
//! Instances auto-reset: when an episode returns its `Last` timestep,
//! the *next* step call resets that instance (its action is ignored)
//! and yields the fresh `First` step in that slot, so the batch never
//! shrinks and the policy artifact always sees a full `[B, N, O]`
//! input. This is the dispatch-amortisation trick behind the paper's
//! speed claim (Mava §5, Fig 6): one PJRT call per *vector* step
//! instead of one per environment step.

use anyhow::{ensure, Result};

use crate::core::{
    Actions, ActionsRef, EnvSpec, HostTensor, StepMeta, StepType, TimeStep,
};
use crate::env::MultiAgentEnv;

/// One synchronized step of all environment instances (legacy
/// array-of-structs form).
///
/// `steps[i]` is instance `i`'s latest [`TimeStep`]; slots whose episode
/// just auto-reset hold a `First` step. [`VecStep::stacked_obs`] packs the
/// per-instance observations into the `[B, N, O]` tensor the batched
/// policy artifact consumes.
#[derive(Clone, Debug)]
pub struct VecStep {
    /// Per-instance timesteps, indexed by environment slot.
    pub steps: Vec<TimeStep>,
}

impl VecStep {
    /// Number of environment instances in the batch.
    pub fn num_envs(&self) -> usize {
        self.steps.len()
    }

    /// Stack every instance's observations into one `[B, N, O]` tensor.
    pub fn stacked_obs(&self) -> HostTensor {
        let b = self.steps.len();
        let n = self.steps[0].observations.len();
        let o = self.steps[0].observations[0].len();
        let mut data = Vec::with_capacity(b * n * o);
        for ts in &self.steps {
            debug_assert_eq!(ts.observations.len(), n);
            for obs in &ts.observations {
                debug_assert_eq!(obs.len(), o);
                data.extend_from_slice(obs);
            }
        }
        HostTensor::f32(vec![b, n, o], data)
    }

    /// True when any instance's episode ended on this vector step.
    pub fn any_last(&self) -> bool {
        self.steps.iter().any(|ts| ts.is_last())
    }
}

/// Struct-of-arrays batch of one vector step: the reusable buffer the
/// whole env → policy → adder hot path flows through (DESIGN.md §6).
///
/// One contiguous plane per field — `[B, N, O]` observations,
/// `[B, N]` rewards, per-row step types and discounts, `[B, S]` global
/// state and (for masked environments) a `[B, N, A]` legal-action
/// plane. The buffer is allocated once ([`VecEnv::make_buf`]) and
/// refilled in place every step; callers typically keep two and swap
/// (double buffering), so the previous step's tensors stay readable
/// while the next step is produced.
#[derive(Clone, Debug)]
pub struct VecStepBuf {
    b: usize,
    n: usize,
    o: usize,
    a: usize,
    s: usize,
    /// Stacked observations `[B, N, O]` — uploaded as-is to the batched
    /// policy artifact.
    pub obs: HostTensor,
    rewards: Vec<f32>,
    step_types: Vec<StepType>,
    discounts: Vec<f32>,
    legal: Option<Vec<f32>>,
    state: Vec<f32>,
}

impl VecStepBuf {
    /// An all-zero buffer for `b` instances of `spec`; `with_legal`
    /// adds the `[B, N, A]` mask plane. Fresh rows read as `Mid` steps
    /// with zero discount/rewards — the pad-safe defaults: rows beyond
    /// the real instance count of a bucket-padded buffer (DESIGN.md
    /// §11) keep these values forever, so they never read as episode
    /// ends (`any_last`) and never contribute reward.
    pub fn new(spec: &EnvSpec, b: usize, with_legal: bool) -> VecStepBuf {
        let (n, o, s) = (spec.n_agents, spec.obs_dim, spec.state_dim);
        let a = spec.n_actions();
        VecStepBuf {
            b,
            n,
            o,
            a,
            s,
            obs: HostTensor::zeros_f32(vec![b, n, o]),
            rewards: vec![0.0; b * n],
            step_types: vec![StepType::Mid; b],
            discounts: vec![0.0; b],
            legal: with_legal.then(|| vec![0.0; b * n * a]),
            state: vec![0.0; b * s],
        }
    }

    /// Number of environment instances.
    pub fn num_envs(&self) -> usize {
        self.b
    }

    /// Number of agents per instance.
    pub fn n_agents(&self) -> usize {
        self.n
    }

    /// Per-agent observation dim.
    pub fn obs_dim(&self) -> usize {
        self.o
    }

    /// Per-agent action count (mask width).
    pub fn n_actions(&self) -> usize {
        self.a
    }

    /// Row `i`'s step type.
    pub fn step_type(&self, i: usize) -> StepType {
        self.step_types[i]
    }

    /// True when row `i` holds a `Last` step.
    pub fn is_last(&self, i: usize) -> bool {
        self.step_types[i] == StepType::Last
    }

    /// True when any row's episode ended on this vector step.
    pub fn any_last(&self) -> bool {
        self.step_types.iter().any(|&t| t == StepType::Last)
    }

    /// Row `i`'s bootstrap discount.
    pub fn discount(&self, i: usize) -> f32 {
        self.discounts[i]
    }

    /// Row `i`'s stacked observations `[N*O]`.
    pub fn obs_row(&self, i: usize) -> &[f32] {
        self.obs.f32_chunk(i, self.n * self.o)
    }

    /// Row `i`'s per-agent rewards `[N]`.
    pub fn rewards_row(&self, i: usize) -> &[f32] {
        &self.rewards[i * self.n..(i + 1) * self.n]
    }

    /// Mutable view of row `i`'s per-agent rewards (padding-poisoning
    /// tests and external reward shaping).
    pub fn rewards_row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.rewards[i * self.n..(i + 1) * self.n]
    }

    /// Row `i`'s mean-over-agents reward (episode-return accounting).
    pub fn mean_reward(&self, i: usize) -> f32 {
        let r = self.rewards_row(i);
        r.iter().sum::<f32>() / r.len().max(1) as f32
    }

    /// Row `i`'s global state `[S]` (empty when the env has none).
    pub fn state_row(&self, i: usize) -> &[f32] {
        &self.state[i * self.s..(i + 1) * self.s]
    }

    /// Row `i`'s legal-action mask `[N*A]` (None when unmasked).
    pub fn legal_row(&self, i: usize) -> Option<&[f32]> {
        let (n, a) = (self.n, self.a);
        self.legal.as_ref().map(|l| &l[i * n * a..(i + 1) * n * a])
    }

    /// Agent `j`'s legal mask `[A]` within row `i`.
    pub fn legal_agent(&self, i: usize, j: usize) -> Option<&[f32]> {
        self.legal_row(i).map(|row| &row[j * self.a..(j + 1) * self.a])
    }

    /// Overwrite row `i` from an owned [`TimeStep`] (the bridge for
    /// environments without SoA hooks, and for tests).
    pub fn scatter(&mut self, i: usize, ts: &TimeStep) {
        debug_assert_eq!(ts.observations.len(), self.n);
        let (n, o, a) = (self.n, self.o, self.a);
        let dst = self.obs.f32_chunk_mut(i, n * o);
        for (j, src) in ts.observations.iter().enumerate() {
            debug_assert_eq!(src.len(), o);
            dst[j * o..(j + 1) * o].copy_from_slice(src);
        }
        self.rewards[i * n..(i + 1) * n].copy_from_slice(&ts.rewards);
        debug_assert_eq!(ts.state.len(), self.s);
        self.state[i * self.s..(i + 1) * self.s]
            .copy_from_slice(&ts.state);
        match (&mut self.legal, &ts.legal_actions) {
            (Some(plane), Some(mask)) => {
                let row = &mut plane[i * n * a..(i + 1) * n * a];
                for (j, m) in mask.iter().enumerate() {
                    for (k, &ok) in m.iter().enumerate() {
                        row[j * a + k] = ok as u8 as f32;
                    }
                }
            }
            (Some(plane), None) => {
                // unmasked step in a masked batch: everything legal
                plane[i * n * a..(i + 1) * n * a].fill(1.0);
            }
            // loud in release too: dropping the mask here would let
            // ε-greedy silently pick illegal actions downstream
            (None, Some(_)) => panic!(
                "env produced legal_actions but has_legal() is false, so \
                 the batch has no mask plane; override \
                 MultiAgentEnv::has_legal() to return true for this env"
            ),
            (None, None) => {}
        }
        self.step_types[i] = ts.step_type;
        self.discounts[i] = ts.discount;
    }

    /// Set row `i`'s scalar step results (internal to the SoA fill).
    fn set_meta(&mut self, i: usize, meta: StepMeta) {
        self.step_types[i] = meta.step_type;
        self.discounts[i] = meta.discount;
    }
}

/// Flat struct-of-arrays joint-action batch: the executor writes one
/// row per environment instance, [`VecEnv::step_into`] lends each row
/// back out as an [`ActionsRef`]. Allocated once and reused.
#[derive(Clone, Debug)]
pub struct ActionBuf {
    b: usize,
    n: usize,
    dim: usize,
    discrete: bool,
    disc: Vec<i32>,
    cont: Vec<f32>,
}

impl ActionBuf {
    /// An all-zero action batch for `b` instances of `spec`.
    pub fn new(spec: &EnvSpec, b: usize) -> ActionBuf {
        let n = spec.n_agents;
        let dim = spec.n_actions();
        let discrete = spec.discrete();
        ActionBuf {
            b,
            n,
            dim,
            discrete,
            disc: if discrete { vec![0; b * n] } else { vec![] },
            cont: if discrete { vec![] } else { vec![0.0; b * n * dim] },
        }
    }

    /// Number of environment instances.
    pub fn num_envs(&self) -> usize {
        self.b
    }

    /// True for discrete action spaces.
    pub fn discrete(&self) -> bool {
        self.discrete
    }

    /// Borrow row `i` as a joint action.
    pub fn row(&self, i: usize) -> ActionsRef<'_> {
        if self.discrete {
            ActionsRef::Discrete(&self.disc[i * self.n..(i + 1) * self.n])
        } else {
            let w = self.n * self.dim;
            ActionsRef::Continuous {
                data: &self.cont[i * w..(i + 1) * w],
                dim: self.dim,
            }
        }
    }

    /// Mutable discrete row `[N]` (panics on continuous buffers).
    pub fn disc_row_mut(&mut self, i: usize) -> &mut [i32] {
        assert!(self.discrete, "discrete row of a continuous ActionBuf");
        &mut self.disc[i * self.n..(i + 1) * self.n]
    }

    /// Mutable continuous row `[N*dim]` (panics on discrete buffers).
    pub fn cont_row_mut(&mut self, i: usize) -> &mut [f32] {
        assert!(!self.discrete, "continuous row of a discrete ActionBuf");
        let w = self.n * self.dim;
        &mut self.cont[i * w..(i + 1) * w]
    }

    /// Overwrite row `i` from an owned joint action (tests / bridges).
    pub fn set_row(&mut self, i: usize, actions: &Actions) {
        match actions {
            Actions::Discrete(a) => {
                self.disc_row_mut(i).copy_from_slice(a);
            }
            Actions::Continuous(a) => {
                let dim = self.dim;
                let row = self.cont_row_mut(i);
                for (j, aj) in a.iter().enumerate() {
                    row[j * dim..(j + 1) * dim].copy_from_slice(aj);
                }
            }
        }
    }
}

/// `B` instances of one environment stepped in lockstep with auto-reset.
///
/// All instances must share the same [`EnvSpec`] (they may differ in
/// seed). See the module docs for the auto-reset protocol and the two
/// stepping APIs.
pub struct VecEnv {
    envs: Vec<Box<dyn MultiAgentEnv>>,
    spec: EnvSpec,
    has_legal: bool,
    /// step type each instance last returned; `Last` marks slots that
    /// auto-reset on the next `step` call.
    last_types: Vec<StepType>,
}

impl VecEnv {
    /// Build from pre-constructed instances (differently seeded copies of
    /// the same environment). Fails on an empty batch or any spec
    /// mismatch — agent count, observation dim, action space, state
    /// dim, episode limit and legal-mask support must all agree, or a
    /// lowered `[B, N, O]` artifact (and the shared SoA buffer) could
    /// not serve every slot.
    pub fn new(envs: Vec<Box<dyn MultiAgentEnv>>) -> Result<VecEnv> {
        ensure!(!envs.is_empty(), "VecEnv needs at least one instance");
        let spec = envs[0].spec().clone();
        let has_legal = envs[0].has_legal();
        for (i, e) in envs.iter().enumerate().skip(1) {
            let s = e.spec();
            ensure!(
                s.n_agents == spec.n_agents && s.obs_dim == spec.obs_dim,
                "instance {i} spec mismatch: {}x{} vs {}x{}",
                s.n_agents,
                s.obs_dim,
                spec.n_agents,
                spec.obs_dim
            );
            ensure!(
                s.action == spec.action,
                "instance {i} action spec mismatch: {:?} vs {:?}",
                s.action,
                spec.action
            );
            ensure!(
                s.state_dim == spec.state_dim,
                "instance {i} state_dim mismatch: {} vs {}",
                s.state_dim,
                spec.state_dim
            );
            ensure!(
                s.episode_limit == spec.episode_limit,
                "instance {i} episode_limit mismatch: {} vs {}",
                s.episode_limit,
                spec.episode_limit
            );
            ensure!(
                e.has_legal() == has_legal,
                "instance {i} legal-mask support mismatch"
            );
        }
        let b = envs.len();
        Ok(VecEnv {
            envs,
            spec,
            has_legal,
            last_types: vec![StepType::Last; b],
        })
    }

    /// Number of environment instances.
    pub fn num_envs(&self) -> usize {
        self.envs.len()
    }

    /// Shared environment spec (all instances match).
    pub fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    /// Whether the batch carries a legal-action mask plane.
    pub fn has_legal(&self) -> bool {
        self.has_legal
    }

    /// A [`VecStepBuf`] shaped for this batch (allocate once, refill
    /// every step).
    pub fn make_buf(&self) -> VecStepBuf {
        VecStepBuf::new(&self.spec, self.envs.len(), self.has_legal)
    }

    /// An [`ActionBuf`] shaped for this batch.
    pub fn make_action_buf(&self) -> ActionBuf {
        ActionBuf::new(&self.spec, self.envs.len())
    }

    /// A [`VecStepBuf`] padded to `width >= num_envs` rows — the
    /// bucketed-lowering path (DESIGN.md §11): the buffer matches a
    /// lowered policy bucket while only the first `num_envs` rows carry
    /// real environments. Padding rows stay zeroed (`StepType::Mid`,
    /// zero obs/rewards/discount) and are never written by
    /// [`VecEnv::reset_into`] / [`VecEnv::step_into`].
    pub fn make_buf_padded(&self, width: usize) -> VecStepBuf {
        assert!(width >= self.envs.len(), "pad width below num_envs");
        VecStepBuf::new(&self.spec, width, self.has_legal)
    }

    /// An [`ActionBuf`] padded to `width >= num_envs` rows (see
    /// [`VecEnv::make_buf_padded`]).
    pub fn make_action_buf_padded(&self, width: usize) -> ActionBuf {
        assert!(width >= self.envs.len(), "pad width below num_envs");
        ActionBuf::new(&self.spec, width)
    }

    /// Fill one row of `buf` from `env`'s current post-step state,
    /// via the SoA hooks when available, else by bridging the
    /// materialised timestep (allocates).
    fn fill_row(
        env: &mut Box<dyn MultiAgentEnv>,
        meta: StepMeta,
        buf: &mut VecStepBuf,
        i: usize,
    ) {
        let (n, o, s) = (buf.n, buf.o, buf.s);
        env.write_obs(buf.obs.f32_chunk_mut(i, n * o));
        env.write_rewards(&mut buf.rewards[i * n..(i + 1) * n]);
        if s > 0 {
            env.write_state(&mut buf.state[i * s..(i + 1) * s]);
        }
        if let Some(plane) = &mut buf.legal {
            let w = buf.n * buf.a;
            env.write_legal(&mut plane[i * w..(i + 1) * w]);
        }
        buf.set_meta(i, meta);
    }

    /// Reset every instance **into** `buf`: every real row comes back
    /// as a `First` step. Allocation-free for SoA environments. `buf`
    /// may be wider than the instance count (bucket padding,
    /// [`VecEnv::make_buf_padded`]); rows past `num_envs` are left
    /// untouched.
    pub fn reset_into(&mut self, buf: &mut VecStepBuf) {
        assert!(buf.num_envs() >= self.envs.len(), "buf batch < num_envs");
        for (i, env) in self.envs.iter_mut().enumerate() {
            if env.writes_soa() {
                let meta = env.reset_soa();
                Self::fill_row(env, meta, buf, i);
            } else {
                let ts = env.reset();
                buf.scatter(i, &ts);
            }
            self.last_types[i] = StepType::First;
        }
    }

    /// Step every instance with its [`ActionBuf`] row **into** `buf`.
    /// Instances whose previous step was `Last` are reset instead
    /// (their action row is ignored) and contribute a `First` row.
    /// Allocation-free for SoA environments. Both buffers may be wider
    /// than the instance count (bucket padding); rows past `num_envs`
    /// are neither read nor written.
    pub fn step_into(&mut self, actions: &ActionBuf, buf: &mut VecStepBuf) {
        assert!(
            actions.num_envs() >= self.envs.len(),
            "actions batch < num_envs"
        );
        assert!(buf.num_envs() >= self.envs.len(), "buf batch < num_envs");
        for (i, env) in self.envs.iter_mut().enumerate() {
            let resets = self.last_types[i] == StepType::Last;
            if env.writes_soa() {
                let meta = if resets {
                    env.reset_soa()
                } else {
                    env.step_soa(&actions.row(i))
                };
                Self::fill_row(env, meta, buf, i);
                self.last_types[i] = meta.step_type;
            } else {
                // bridge: materialise a TimeStep (allocates)
                let ts = if resets {
                    env.reset()
                } else {
                    env.step(&actions.row(i).to_actions())
                };
                buf.scatter(i, &ts);
                self.last_types[i] = ts.step_type;
            }
        }
    }

    /// Reset every instance; returns a batch of `First` timesteps
    /// (legacy allocating API).
    pub fn reset(&mut self) -> VecStep {
        let steps: Vec<TimeStep> =
            self.envs.iter_mut().map(|e| e.reset()).collect();
        for t in &mut self.last_types {
            *t = StepType::First;
        }
        VecStep { steps }
    }

    /// Step every instance with its joint action (legacy allocating
    /// API). Instances whose previous timestep was `Last` are reset
    /// instead (their action is ignored) and contribute a `First`
    /// timestep.
    pub fn step(&mut self, actions: &[Actions]) -> VecStep {
        assert_eq!(
            actions.len(),
            self.envs.len(),
            "actions batch != num_envs"
        );
        let mut steps = Vec::with_capacity(self.envs.len());
        for (i, env) in self.envs.iter_mut().enumerate() {
            let ts = if self.last_types[i] == StepType::Last {
                env.reset()
            } else {
                env.step(&actions[i])
            };
            self.last_types[i] = ts.step_type;
            steps.push(ts);
        }
        VecStep { steps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ActionSpec;

    /// Deterministic env with a per-instance episode length so tests can
    /// desynchronise instances; observation = [instance id, t].
    struct TestEnv {
        spec: EnvSpec,
        id: f32,
        limit: usize,
        t: usize,
    }

    impl TestEnv {
        fn new(id: f32, limit: usize) -> Self {
            TestEnv {
                spec: EnvSpec {
                    name: "test".into(),
                    n_agents: 2,
                    obs_dim: 2,
                    action: ActionSpec::Discrete { n: 3 },
                    state_dim: 0,
                    episode_limit: limit,
                },
                id,
                limit,
                t: 0,
            }
        }

        fn obs(&self) -> Vec<Vec<f32>> {
            vec![vec![self.id, self.t as f32]; 2]
        }
    }

    impl MultiAgentEnv for TestEnv {
        fn spec(&self) -> &EnvSpec {
            &self.spec
        }

        fn reset(&mut self) -> TimeStep {
            self.t = 0;
            TimeStep {
                step_type: StepType::First,
                observations: self.obs(),
                rewards: vec![0.0; 2],
                discount: 1.0,
                state: vec![],
                legal_actions: None,
            }
        }

        fn step(&mut self, _actions: &Actions) -> TimeStep {
            self.t += 1;
            let last = self.t >= self.limit;
            TimeStep {
                step_type: if last { StepType::Last } else { StepType::Mid },
                observations: self.obs(),
                rewards: vec![1.0; 2],
                discount: 1.0,
                state: vec![],
                legal_actions: None,
            }
        }
    }

    fn acts(b: usize) -> Vec<Actions> {
        vec![Actions::Discrete(vec![0, 0]); b]
    }

    #[test]
    fn stacked_obs_layout_is_instance_major() {
        let envs: Vec<Box<dyn MultiAgentEnv>> = (0..3)
            .map(|i| -> Box<dyn MultiAgentEnv> {
                Box::new(TestEnv::new(i as f32, 4))
            })
            .collect();
        let mut venv = VecEnv::new(envs).unwrap();
        let vs = venv.reset();
        let obs = vs.stacked_obs();
        assert_eq!(obs.dims, vec![3, 2, 2]);
        // row-major [B, N, O]: instance i, agent j at offset (i*2+j)*2
        let d = obs.as_f32();
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(d[(i * 2 + j) * 2], i as f32, "instance id");
                assert_eq!(d[(i * 2 + j) * 2 + 1], 0.0, "t after reset");
            }
        }
    }

    #[test]
    fn auto_reset_replaces_terminal_slots() {
        // instance 0 ends after 2 steps, instance 1 after 4... but the
        // spec validator now (correctly) rejects mismatched episode
        // limits, so desynchronise via the buf path below instead; here
        // both end after 2 steps.
        let envs: Vec<Box<dyn MultiAgentEnv>> = vec![
            Box::new(TestEnv::new(0.0, 2)),
            Box::new(TestEnv::new(1.0, 2)),
        ];
        let mut venv = VecEnv::new(envs).unwrap();
        let mut vs = venv.reset();
        assert!(vs.steps.iter().all(|t| t.step_type == StepType::First));

        vs = venv.step(&acts(2)); // t=1: both Mid
        assert!(vs.steps.iter().all(|t| t.step_type == StepType::Mid));
        vs = venv.step(&acts(2)); // t=2: both Last
        assert!(vs.steps.iter().all(|t| t.step_type == StepType::Last));
        assert!(vs.any_last());

        // next step auto-resets both slots
        vs = venv.step(&acts(2));
        assert_eq!(vs.steps[0].step_type, StepType::First);
        assert_eq!(vs.steps[0].observations[0][1], 0.0, "t reset to 0");

        // batch size never changes across the boundary
        assert_eq!(vs.num_envs(), 2);
        assert_eq!(vs.stacked_obs().dims, vec![2, 2, 2]);
    }

    #[test]
    fn spec_mismatch_rejected() {
        let a = Box::new(TestEnv::new(0.0, 2)) as Box<dyn MultiAgentEnv>;
        let mut b = TestEnv::new(1.0, 2);
        b.spec.obs_dim = 5;
        assert!(VecEnv::new(vec![a, Box::new(b)]).is_err());
        assert!(VecEnv::new(vec![]).is_err());
    }

    #[test]
    fn action_state_and_limit_mismatches_rejected() {
        fn pair(
            f: impl FnOnce(&mut TestEnv),
        ) -> Result<VecEnv> {
            let a = Box::new(TestEnv::new(0.0, 2)) as Box<dyn MultiAgentEnv>;
            let mut b = TestEnv::new(1.0, 2);
            f(&mut b);
            VecEnv::new(vec![a, Box::new(b)])
        }
        assert!(pair(|_| {}).is_ok());
        assert!(pair(|e| e.spec.action = ActionSpec::Discrete { n: 4 })
            .is_err());
        assert!(pair(
            |e| e.spec.action = ActionSpec::Continuous { dim: 3 }
        )
        .is_err());
        assert!(pair(|e| e.spec.state_dim = 7).is_err());
        assert!(pair(|e| {
            e.spec.episode_limit = 9;
            e.limit = 9;
        })
        .is_err());
    }

    #[test]
    fn works_with_real_env() {
        use crate::env::make_env;
        let envs: Vec<Box<dyn MultiAgentEnv>> = (0..4)
            .map(|i| make_env("matrix", i).unwrap())
            .collect();
        let mut venv = VecEnv::new(envs).unwrap();
        let mut vs = venv.reset();
        // matrix episodes are 5 steps; drive across two boundaries
        let mut firsts = 0;
        for _ in 0..12 {
            vs = venv.step(&acts(4));
            firsts += vs
                .steps
                .iter()
                .filter(|t| t.step_type == StepType::First)
                .count();
            assert_eq!(vs.stacked_obs().dims, vec![4, 2, 4]);
        }
        // 12 vector steps = 2 auto-resets per instance (t=6 and t=12)
        assert_eq!(firsts, 8);
    }

    /// The SoA buf path and the legacy VecStep path must produce
    /// identical batches for identical action streams, including
    /// across auto-reset boundaries.
    #[test]
    fn step_into_matches_legacy_step() {
        use crate::env::make_env;
        for name in [
            "matrix",
            "switch",
            "smac_lite",
            "mpe_spread",
            "mpe_speaker_listener",
            "multiwalker",
        ] {
            let mk = |off: u64| -> Vec<Box<dyn MultiAgentEnv>> {
                (0..3).map(|i| make_env(name, off + i).unwrap()).collect()
            };
            let mut legacy = VecEnv::new(mk(10)).unwrap();
            let mut soa = VecEnv::new(mk(10)).unwrap();
            assert!(soa.envs.iter().all(|e| e.writes_soa()), "{name}");

            let spec = soa.spec().clone();
            let mut buf = soa.make_buf();
            let mut abuf = soa.make_action_buf();
            let vs0 = legacy.reset();
            soa.reset_into(&mut buf);
            compare(&vs0, &buf, name);

            let mut rng = crate::rng::Rng::new(42);
            for _ in 0..2 * spec.episode_limit.min(40) + 3 {
                // one shared random joint-action batch
                let actions: Vec<Actions> = (0..3)
                    .map(|_| match spec.action {
                        ActionSpec::Discrete { n } => Actions::Discrete(
                            (0..spec.n_agents)
                                .map(|_| rng.below(n) as i32)
                                .collect(),
                        ),
                        ActionSpec::Continuous { dim } => {
                            Actions::Continuous(
                                (0..spec.n_agents)
                                    .map(|_| {
                                        (0..dim)
                                            .map(|_| {
                                                rng.range_f32(-1.0, 1.0)
                                            })
                                            .collect()
                                    })
                                    .collect(),
                            )
                        }
                    })
                    .collect();
                for (i, a) in actions.iter().enumerate() {
                    abuf.set_row(i, a);
                }
                let vs = legacy.step(&actions);
                soa.step_into(&abuf, &mut buf);
                compare(&vs, &buf, name);
            }
        }

        fn compare(vs: &VecStep, buf: &VecStepBuf, name: &str) {
            for (i, ts) in vs.steps.iter().enumerate() {
                assert_eq!(ts.step_type, buf.step_type(i), "{name} row {i}");
                assert_eq!(ts.discount, buf.discount(i), "{name} row {i}");
                let flat: Vec<f32> = ts.observations.concat();
                assert_eq!(flat, buf.obs_row(i), "{name} obs row {i}");
                assert_eq!(
                    ts.rewards,
                    buf.rewards_row(i),
                    "{name} rewards row {i}"
                );
                assert_eq!(ts.state, buf.state_row(i), "{name} state row {i}");
                match (&ts.legal_actions, buf.legal_row(i)) {
                    (Some(mask), Some(row)) => {
                        let want: Vec<f32> = mask
                            .iter()
                            .flatten()
                            .map(|&b| b as u8 as f32)
                            .collect();
                        assert_eq!(want, row, "{name} legal row {i}");
                    }
                    (None, None) => {}
                    other => {
                        panic!("{name} legal plane mismatch: {other:?}")
                    }
                }
            }
        }
    }

    /// Bucket padding (DESIGN.md §11): a buffer wider than the instance
    /// count fills only the real rows; pad rows are bitwise untouched
    /// across resets and steps, and real rows match an unpadded run.
    #[test]
    fn padded_buf_real_rows_match_and_pad_rows_untouched() {
        use crate::env::make_env;
        let mk = |n: u64| -> Vec<Box<dyn MultiAgentEnv>> {
            (0..n).map(|i| make_env("matrix", i).unwrap()).collect()
        };
        let mut plain = VecEnv::new(mk(3)).unwrap();
        let mut padded = VecEnv::new(mk(3)).unwrap();
        let mut buf = plain.make_buf();
        let mut pbuf = padded.make_buf_padded(8);
        let mut abuf = plain.make_action_buf();
        let mut pabuf = padded.make_action_buf_padded(8);
        assert_eq!(pbuf.num_envs(), 8);

        // poison the pad rows' action slots; they must never be read
        for i in 3..8 {
            pabuf.disc_row_mut(i).fill(99);
        }
        plain.reset_into(&mut buf);
        padded.reset_into(&mut pbuf);
        for _ in 0..12 {
            for i in 0..3 {
                for (a, b) in abuf
                    .disc_row_mut(i)
                    .iter_mut()
                    .zip(pabuf.disc_row_mut(i).iter_mut())
                {
                    *a = 1;
                    *b = 1;
                }
            }
            plain.step_into(&abuf, &mut buf);
            padded.step_into(&pabuf, &mut pbuf);
            for i in 0..3 {
                assert_eq!(buf.obs_row(i), pbuf.obs_row(i), "row {i}");
                assert_eq!(buf.rewards_row(i), pbuf.rewards_row(i));
                assert_eq!(buf.step_type(i), pbuf.step_type(i));
                assert_eq!(buf.discount(i), pbuf.discount(i));
            }
            for i in 3..8 {
                assert!(
                    pbuf.obs_row(i).iter().all(|&x| x == 0.0),
                    "pad row {i} was written"
                );
                assert_eq!(pbuf.discount(i), 0.0, "pad row {i} discount");
                assert_ne!(
                    pbuf.step_type(i),
                    StepType::Last,
                    "pad row {i} must never read as episode end"
                );
            }
        }
    }

    /// Non-SoA environments bridge through the timestep API — same
    /// results, just allocating.
    #[test]
    fn bridged_env_fills_buf() {
        let envs: Vec<Box<dyn MultiAgentEnv>> = vec![
            Box::new(TestEnv::new(0.0, 2)),
            Box::new(TestEnv::new(1.0, 2)),
        ];
        let mut venv = VecEnv::new(envs).unwrap();
        let mut buf = venv.make_buf();
        let mut abuf = venv.make_action_buf();
        venv.reset_into(&mut buf);
        assert_eq!(buf.obs_row(1), &[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(buf.step_type(0), StepType::First);
        for expect in [StepType::Mid, StepType::Last, StepType::First] {
            venv.step_into(&abuf, &mut buf);
            assert_eq!(buf.step_type(0), expect);
        }
        let _ = abuf.row(0); // rows stay borrowable
    }
}
