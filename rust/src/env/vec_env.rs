//! Batched environment execution: the executor-side half of the
//! vectorized hot path (DESIGN.md §6).
//!
//! A [`VecEnv`] owns `B = num_envs_per_executor` instances of any
//! [`MultiAgentEnv`] and steps them together, exposing stacked
//! `[B, N, obs]` observations so a single batched policy-artifact call
//! can act for every instance at once. Instances auto-reset: when an
//! episode returns its `Last` timestep, the *next* [`VecEnv::step`] call
//! resets that instance (its action is ignored) and returns the fresh
//! `First` timestep in that slot, so the batch never shrinks and the
//! policy artifact always sees a full `[B, N, O]` input.
//!
//! This is the dispatch-amortisation trick behind the paper's speed
//! claim (Mava §5, Fig 6): one PJRT call per *vector* step instead of
//! one per environment step.

use anyhow::{ensure, Result};

use crate::core::{Actions, EnvSpec, HostTensor, StepType, TimeStep};
use crate::env::MultiAgentEnv;

/// One synchronized step of all environment instances.
///
/// `steps[i]` is instance `i`'s latest [`TimeStep`]; slots whose episode
/// just auto-reset hold a `First` step. [`VecStep::stacked_obs`] packs the
/// per-instance observations into the `[B, N, O]` tensor the batched
/// policy artifact consumes.
#[derive(Clone, Debug)]
pub struct VecStep {
    /// Per-instance timesteps, indexed by environment slot.
    pub steps: Vec<TimeStep>,
}

impl VecStep {
    /// Number of environment instances in the batch.
    pub fn num_envs(&self) -> usize {
        self.steps.len()
    }

    /// Stack every instance's observations into one `[B, N, O]` tensor.
    pub fn stacked_obs(&self) -> HostTensor {
        let b = self.steps.len();
        let n = self.steps[0].observations.len();
        let o = self.steps[0].observations[0].len();
        let mut data = Vec::with_capacity(b * n * o);
        for ts in &self.steps {
            debug_assert_eq!(ts.observations.len(), n);
            for obs in &ts.observations {
                debug_assert_eq!(obs.len(), o);
                data.extend_from_slice(obs);
            }
        }
        HostTensor::f32(vec![b, n, o], data)
    }

    /// True when any instance's episode ended on this vector step.
    pub fn any_last(&self) -> bool {
        self.steps.iter().any(|ts| ts.is_last())
    }
}

/// `B` instances of one environment stepped in lockstep with auto-reset.
///
/// All instances must share the same spec shape (`n_agents`, `obs_dim`);
/// they may differ in seed. See the module docs for the auto-reset
/// protocol.
pub struct VecEnv {
    envs: Vec<Box<dyn MultiAgentEnv>>,
    spec: EnvSpec,
    /// step type each instance last returned; `Last` marks slots that
    /// auto-reset on the next `step` call.
    last_types: Vec<StepType>,
}

impl VecEnv {
    /// Build from pre-constructed instances (differently seeded copies of
    /// the same environment). Fails on an empty batch or mismatched
    /// specs.
    pub fn new(envs: Vec<Box<dyn MultiAgentEnv>>) -> Result<VecEnv> {
        ensure!(!envs.is_empty(), "VecEnv needs at least one instance");
        let spec = envs[0].spec().clone();
        for (i, e) in envs.iter().enumerate().skip(1) {
            let s = e.spec();
            ensure!(
                s.n_agents == spec.n_agents && s.obs_dim == spec.obs_dim,
                "instance {i} spec mismatch: {}x{} vs {}x{}",
                s.n_agents,
                s.obs_dim,
                spec.n_agents,
                spec.obs_dim
            );
        }
        let b = envs.len();
        Ok(VecEnv { envs, spec, last_types: vec![StepType::Last; b] })
    }

    /// Number of environment instances.
    pub fn num_envs(&self) -> usize {
        self.envs.len()
    }

    /// Shared environment spec (all instances match).
    pub fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    /// Reset every instance; returns a batch of `First` timesteps.
    pub fn reset(&mut self) -> VecStep {
        let steps: Vec<TimeStep> =
            self.envs.iter_mut().map(|e| e.reset()).collect();
        for t in &mut self.last_types {
            *t = StepType::First;
        }
        VecStep { steps }
    }

    /// Step every instance with its joint action. Instances whose
    /// previous timestep was `Last` are reset instead (their action is
    /// ignored) and contribute a `First` timestep.
    pub fn step(&mut self, actions: &[Actions]) -> VecStep {
        assert_eq!(
            actions.len(),
            self.envs.len(),
            "actions batch != num_envs"
        );
        let mut steps = Vec::with_capacity(self.envs.len());
        for (i, env) in self.envs.iter_mut().enumerate() {
            let ts = if self.last_types[i] == StepType::Last {
                env.reset()
            } else {
                env.step(&actions[i])
            };
            self.last_types[i] = ts.step_type;
            steps.push(ts);
        }
        VecStep { steps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ActionSpec;

    /// Deterministic env with a per-instance episode length so tests can
    /// desynchronise instances; observation = [instance id, t].
    struct TestEnv {
        spec: EnvSpec,
        id: f32,
        limit: usize,
        t: usize,
    }

    impl TestEnv {
        fn new(id: f32, limit: usize) -> Self {
            TestEnv {
                spec: EnvSpec {
                    name: "test".into(),
                    n_agents: 2,
                    obs_dim: 2,
                    action: ActionSpec::Discrete { n: 3 },
                    state_dim: 0,
                    episode_limit: limit,
                },
                id,
                limit,
                t: 0,
            }
        }

        fn obs(&self) -> Vec<Vec<f32>> {
            vec![vec![self.id, self.t as f32]; 2]
        }
    }

    impl MultiAgentEnv for TestEnv {
        fn spec(&self) -> &EnvSpec {
            &self.spec
        }

        fn reset(&mut self) -> TimeStep {
            self.t = 0;
            TimeStep {
                step_type: StepType::First,
                observations: self.obs(),
                rewards: vec![0.0; 2],
                discount: 1.0,
                state: vec![],
                legal_actions: None,
            }
        }

        fn step(&mut self, _actions: &Actions) -> TimeStep {
            self.t += 1;
            let last = self.t >= self.limit;
            TimeStep {
                step_type: if last { StepType::Last } else { StepType::Mid },
                observations: self.obs(),
                rewards: vec![1.0; 2],
                discount: 1.0,
                state: vec![],
                legal_actions: None,
            }
        }
    }

    fn acts(b: usize) -> Vec<Actions> {
        vec![Actions::Discrete(vec![0, 0]); b]
    }

    #[test]
    fn stacked_obs_layout_is_instance_major() {
        let envs: Vec<Box<dyn MultiAgentEnv>> = (0..3)
            .map(|i| -> Box<dyn MultiAgentEnv> {
                Box::new(TestEnv::new(i as f32, 4))
            })
            .collect();
        let mut venv = VecEnv::new(envs).unwrap();
        let vs = venv.reset();
        let obs = vs.stacked_obs();
        assert_eq!(obs.dims, vec![3, 2, 2]);
        // row-major [B, N, O]: instance i, agent j at offset (i*2+j)*2
        let d = obs.as_f32();
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(d[(i * 2 + j) * 2], i as f32, "instance id");
                assert_eq!(d[(i * 2 + j) * 2 + 1], 0.0, "t after reset");
            }
        }
    }

    #[test]
    fn auto_reset_replaces_terminal_slots() {
        // instance 0 ends after 2 steps, instance 1 after 4
        let envs: Vec<Box<dyn MultiAgentEnv>> = vec![
            Box::new(TestEnv::new(0.0, 2)),
            Box::new(TestEnv::new(1.0, 4)),
        ];
        let mut venv = VecEnv::new(envs).unwrap();
        let mut vs = venv.reset();
        assert!(vs.steps.iter().all(|t| t.step_type == StepType::First));

        vs = venv.step(&acts(2)); // t=1: both Mid
        assert!(vs.steps.iter().all(|t| t.step_type == StepType::Mid));
        vs = venv.step(&acts(2)); // t=2: 0 Last, 1 Mid
        assert_eq!(vs.steps[0].step_type, StepType::Last);
        assert_eq!(vs.steps[1].step_type, StepType::Mid);
        assert!(vs.any_last());

        // next step auto-resets slot 0 only
        vs = venv.step(&acts(2));
        assert_eq!(vs.steps[0].step_type, StepType::First);
        assert_eq!(vs.steps[0].observations[0][1], 0.0, "t reset to 0");
        assert_eq!(vs.steps[1].step_type, StepType::Mid);
        assert_eq!(vs.steps[1].observations[0][1], 3.0);

        // batch size never changes across the boundary
        assert_eq!(vs.num_envs(), 2);
        assert_eq!(vs.stacked_obs().dims, vec![2, 2, 2]);
    }

    #[test]
    fn spec_mismatch_rejected() {
        let a = Box::new(TestEnv::new(0.0, 2)) as Box<dyn MultiAgentEnv>;
        let mut b = TestEnv::new(1.0, 2);
        b.spec.obs_dim = 5;
        assert!(VecEnv::new(vec![a, Box::new(b)]).is_err());
        assert!(VecEnv::new(vec![]).is_err());
    }

    #[test]
    fn works_with_real_env() {
        use crate::env::make_env;
        let envs: Vec<Box<dyn MultiAgentEnv>> = (0..4)
            .map(|i| make_env("matrix", i).unwrap())
            .collect();
        let mut venv = VecEnv::new(envs).unwrap();
        let mut vs = venv.reset();
        // matrix episodes are 5 steps; drive across two boundaries
        let mut firsts = 0;
        for _ in 0..12 {
            vs = venv.step(&acts(4));
            firsts += vs
                .steps
                .iter()
                .filter(|t| t.step_type == StepType::First)
                .count();
            assert_eq!(vs.stacked_obs().dims, vec![4, 2, 4]);
        }
        // 12 vector steps = 2 auto-resets per instance (t=6 and t=12)
        assert_eq!(firsts, 8);
    }
}
