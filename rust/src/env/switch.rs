//! The switch riddle game (Foerster et al., 2016) — paper Fig 4 (top).
//!
//! N prisoners; each day one (uniformly random) prisoner is taken to an
//! interrogation room. Agents may communicate only through a 1-bit channel
//! (in DIAL, a learned message replacing the physical switch). Each agent
//! can either do nothing or announce ("Tell") that every prisoner has
//! visited the room. A correct announcement rewards the whole team +1,
//! an incorrect one -1; running out of time gives 0. The optimal policy
//! requires communication, which is what Fig 4 (top) demonstrates: plain
//! (recurrent) MADQN cannot beat random guessing, MADQN + communication
//! (DIAL) learns the riddle.
//!
//! Episode limit 4N-6 as in the original paper.

use crate::core::{
    ActionSpec, Actions, ActionsRef, EnvSpec, StepMeta, StepType, TimeStep,
};
use crate::env::MultiAgentEnv;
use crate::rng::Rng;

/// Action: stay silent this turn.
pub const ACT_NONE: i32 = 0;
/// Action: announce that every agent has visited the room.
pub const ACT_TELL: i32 = 1;

/// The switch riddle (Foerster et al., 2016): one agent per day
/// enters the interrogation room; the team wins only if an agent
/// announces exactly when everyone has visited.
pub struct SwitchGame {
    spec: EnvSpec,
    rng: Rng,
    n: usize,
    limit: usize,
    t: usize,
    in_room: usize,
    has_been: Vec<bool>,
    done: bool,
    last_reward: f32,
}

impl SwitchGame {
    /// An `n_agents` riddle (the paper uses 3).
    pub fn new(n_agents: usize, seed: u64) -> Self {
        assert!(n_agents >= 2);
        let limit = 4 * n_agents - 6;
        SwitchGame {
            spec: EnvSpec {
                name: "switch".into(),
                n_agents,
                obs_dim: 5,
                action: ActionSpec::Discrete { n: 2 },
                state_dim: 0,
                episode_limit: limit,
            },
            rng: Rng::new(seed),
            n: n_agents,
            limit,
            t: 0,
            in_room: 0,
            has_been: vec![false; n_agents],
            done: true,
            last_reward: 0.0,
        }
    }

    fn all_visited(&self) -> bool {
        self.has_been.iter().all(|&b| b)
    }
}

impl MultiAgentEnv for SwitchGame {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let meta = self.reset_soa();
        self.materialize(meta)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let meta = self.step_soa(&ActionsRef::from_actions(actions));
        self.materialize(meta)
    }

    fn writes_soa(&self) -> bool {
        true
    }

    fn reset_soa(&mut self) -> StepMeta {
        self.t = 0;
        self.done = false;
        self.last_reward = 0.0;
        self.has_been.iter_mut().for_each(|b| *b = false);
        self.in_room = self.rng.below(self.n);
        self.has_been[self.in_room] = true;
        StepMeta { step_type: StepType::First, discount: 1.0 }
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        assert!(!self.done, "step() after episode end");
        let acts = actions.as_discrete();
        self.t += 1;

        // Only the agent in the room can effectively announce.
        let announced = acts[self.in_room] == ACT_TELL;
        let (reward, terminal) = if announced {
            (if self.all_visited() { 1.0 } else { -1.0 }, true)
        } else if self.t >= self.limit {
            (0.0, true)
        } else {
            (0.0, false)
        };

        if !terminal {
            self.in_room = self.rng.below(self.n);
            self.has_been[self.in_room] = true;
        } else {
            self.done = true;
        }
        self.last_reward = reward;

        StepMeta {
            step_type: if terminal { StepType::Last } else { StepType::Mid },
            // announcement ends the game for real (discount 0); the time
            // limit is a truncation (discount 1).
            discount: if announced { 0.0 } else { 1.0 },
        }
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        for i in 0..self.n {
            let o = &mut out[i * 5..(i + 1) * 5];
            o[0] = (self.in_room == i) as u8 as f32;
            o[1] = self.has_been[i] as u8 as f32;
            o[2] = self.t as f32 / self.limit as f32;
            o[3] = self.n as f32 / 10.0;
            o[4] = 1.0;
        }
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        out.fill(self.last_reward);
    }

    fn write_state(&mut self, _out: &mut [f32]) {
        // state_dim == 0: never called
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_op(n: usize) -> Actions {
        Actions::Discrete(vec![ACT_NONE; n])
    }

    #[test]
    fn episode_truncates_at_limit() {
        let mut env = SwitchGame::new(3, 1);
        let mut ts = env.reset();
        let mut steps = 0;
        while !ts.is_last() {
            ts = env.step(&no_op(3));
            steps += 1;
        }
        assert_eq!(steps, 6); // 4*3-6
        assert_eq!(ts.rewards[0], 0.0);
    }

    #[test]
    fn correct_tell_rewards_plus_one() {
        // force all agents visited by running long enough, then tell with
        // whoever is in the room
        for seed in 0..20 {
            let mut env = SwitchGame::new(3, seed);
            let ts = env.reset();
            drop(ts);
            // step until everyone has visited
            let mut steps = 0;
            while !env.all_visited() && steps < 5 {
                let ts = env.step(&no_op(3));
                assert!(!ts.is_last() || steps == 5);
                steps += 1;
            }
            if !env.all_visited() {
                continue; // unlucky seed: ran out of room in the limit
            }
            let mut tell = vec![ACT_NONE; 3];
            tell[env.in_room] = ACT_TELL;
            let ts = env.step(&Actions::Discrete(tell));
            assert!(ts.is_last());
            assert_eq!(ts.rewards, vec![1.0; 3]);
            assert_eq!(ts.discount, 0.0);
        }
    }

    #[test]
    fn wrong_tell_rewards_minus_one() {
        let mut env = SwitchGame::new(3, 7);
        env.reset();
        // first step: only one agent has visited; a tell must be wrong
        // unless all have visited (impossible after reset with n=3)
        let mut tell = vec![ACT_NONE; 3];
        tell[env.in_room] = ACT_TELL;
        let ts = env.step(&Actions::Discrete(tell));
        assert!(ts.is_last());
        assert_eq!(ts.rewards, vec![-1.0; 3]);
    }

    #[test]
    fn tell_outside_room_is_noop() {
        let mut env = SwitchGame::new(3, 3);
        env.reset();
        let outside = (env.in_room + 1) % 3;
        let mut tell = vec![ACT_NONE; 3];
        tell[outside] = ACT_TELL;
        let ts = env.step(&Actions::Discrete(tell));
        assert!(!ts.is_last());
        assert_eq!(ts.rewards, vec![0.0; 3]);
    }

    #[test]
    fn obs_shape_and_room_flag() {
        let mut env = SwitchGame::new(3, 5);
        let ts = env.reset();
        assert_eq!(ts.observations.len(), 3);
        let flags: f32 = ts.observations.iter().map(|o| o[0]).sum();
        assert_eq!(flags, 1.0, "exactly one agent in the room");
    }

    #[test]
    fn random_play_runs() {
        let mut env = SwitchGame::new(3, 11);
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            crate::env::random_episode(&mut env, &mut rng);
        }
    }
}
