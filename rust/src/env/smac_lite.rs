//! smac_lite: a StarCraft-free reimplementation of the SMAC 3m
//! micromanagement level — paper Fig 4 (bottom).
//!
//! SC2 is a closed binary, so we rebuild the decision problem the 3m map
//! poses: 3 allied marines (controlled, one per agent) against 3 enemy
//! marines driven by a focus-fire heuristic, on a bounded 2-D arena with
//! SMAC's action set (no-op / stop / move x4 / attack x3), sight & shoot
//! ranges, attack cooldown and the SMAC shaped reward
//! (damage + kill bonus + win bonus, normalised so the maximum episode
//! return is ~20). This keeps the cooperative focus-fire credit-assignment
//! structure that VDN/QMIX exploit — the property Fig 4 (bottom) tests.

use crate::core::{
    ActionSpec, Actions, ActionsRef, EnvSpec, StepMeta, StepType, TimeStep,
};
use crate::env::MultiAgentEnv;
use crate::rng::Rng;

const MAP: f32 = 16.0;
const MAX_HEALTH: f32 = 45.0;
const DAMAGE: f32 = 6.0;
const COOLDOWN: u32 = 1; // steps between shots
const SHOOT_RANGE: f32 = 6.0;
const SIGHT_RANGE: f32 = 9.0;
const MOVE_STEP: f32 = 2.0;
const KILL_BONUS: f32 = 10.0;
const WIN_BONUS: f32 = 200.0;
const REWARD_CAP: f32 = 20.0;

/// Action: no-op (only legal when dead).
pub const ACT_NOOP: usize = 0;
/// Action: hold position.
pub const ACT_STOP: usize = 1;
/// Action: move north (south/east/west follow consecutively).
pub const ACT_MOVE_N: usize = 2; // then S, E, W
/// First attack action; `ACT_ATTACK_0 + i` targets enemy `i`.
pub const ACT_ATTACK_0: usize = 6;

#[derive(Clone, Copy, Debug)]
struct Unit {
    x: f32,
    y: f32,
    health: f32,
    cooldown: u32,
}

impl Unit {
    fn alive(&self) -> bool {
        self.health > 0.0
    }
    fn dist(&self, o: &Unit) -> f32 {
        ((self.x - o.x).powi(2) + (self.y - o.y).powi(2)).sqrt()
    }
}

/// A SMAC-shaped micro battle: `n` marines vs `n` scripted marines
/// with legal-action masks and a global mixer state.
pub struct SmacLite {
    spec: EnvSpec,
    rng: Rng,
    n: usize,
    allies: Vec<Unit>,
    enemies: Vec<Unit>,
    t: usize,
    done: bool,
    max_reward: f32,
    last_reward: f32,
}

impl SmacLite {
    /// The 3-marine map the smac3m preset pins.
    pub fn new_3m(seed: u64) -> Self {
        Self::new(3, seed)
    }

    /// An `n` vs `n` marine battle.
    pub fn new(n: usize, seed: u64) -> Self {
        let obs_dim = 4 + 5 * (n - 1) + 5 * n + 1;
        SmacLite {
            spec: EnvSpec {
                name: "smac_lite".into(),
                n_agents: n,
                obs_dim,
                action: ActionSpec::Discrete { n: 6 + n },
                state_dim: n * obs_dim,
                episode_limit: 60,
            },
            rng: Rng::new(seed),
            n,
            allies: vec![],
            enemies: vec![],
            t: 0,
            done: true,
            max_reward: n as f32 * (MAX_HEALTH + KILL_BONUS) + WIN_BONUS,
            last_reward: 0.0,
        }
    }

    fn spawn(&mut self) {
        // clear+extend keeps the Vec capacity across episodes, so
        // auto-resets on the SoA hot path stay allocation-free
        self.allies.clear();
        let n = self.n;
        let rng = &mut self.rng;
        self.allies.extend((0..n).map(|i| Unit {
            x: 4.0 + rng.range_f32(-0.5, 0.5),
            y: 5.0 + 3.0 * i as f32 + rng.range_f32(-0.5, 0.5),
            health: MAX_HEALTH,
            cooldown: 0,
        }));
        self.enemies.clear();
        self.enemies.extend((0..n).map(|i| Unit {
            x: 12.0 + rng.range_f32(-0.5, 0.5),
            y: 5.0 + 3.0 * i as f32 + rng.range_f32(-0.5, 0.5),
            health: MAX_HEALTH,
            cooldown: 0,
        }));
    }

    fn unit_feats(me: &Unit, other: &Unit, range: f32) -> [f32; 5] {
        if !other.alive() {
            return [0.0; 5];
        }
        let d = me.dist(other);
        if d > range {
            return [0.0; 5];
        }
        [
            1.0,
            d / range,
            (other.x - me.x) / range,
            (other.y - me.y) / range,
            other.health / MAX_HEALTH,
        ]
    }

    fn enemy_turn(&mut self) -> f32 {
        // Heuristic: enemies focus-fire — every living enemy targets the
        // lowest-health reachable ally (ties broken by distance), moving
        // into range if needed and firing when cooled down. Concentrated
        // damage is what makes uncoordinated (independent) ally play
        // lose; coordinated focus-fire + spreading is required to win —
        // the credit-assignment structure Fig 4 (bottom) tests.
        let mut damage_taken = 0.0;
        for e in 0..self.n {
            let enemy = self.enemies[e];
            if !enemy.alive() {
                continue;
            }
            let target = self
                .allies
                .iter()
                .enumerate()
                .filter(|(_, a)| a.alive())
                .min_by(|(_, a), (_, b)| {
                    (a.health, enemy.dist(a))
                        .partial_cmp(&(b.health, enemy.dist(b)))
                        .unwrap()
                })
                .map(|(i, _)| i);
            let Some(ti) = target else { continue };
            let d = enemy.dist(&self.allies[ti]);
            if d <= SHOOT_RANGE && self.enemies[e].cooldown == 0 {
                let dmg = DAMAGE.min(self.allies[ti].health);
                self.allies[ti].health -= dmg;
                damage_taken += dmg;
                self.enemies[e].cooldown = COOLDOWN;
            } else if d > SHOOT_RANGE {
                // advance toward the target
                let (tx, ty) = (self.allies[ti].x, self.allies[ti].y);
                let (dx, dy) = (tx - enemy.x, ty - enemy.y);
                let norm = (dx * dx + dy * dy).sqrt().max(1e-6);
                self.enemies[e].x =
                    (enemy.x + MOVE_STEP * dx / norm).clamp(0.0, MAP);
                self.enemies[e].y =
                    (enemy.y + MOVE_STEP * dy / norm).clamp(0.0, MAP);
            }
            if self.enemies[e].cooldown > 0 && d <= SHOOT_RANGE {
                // tick cooldown only when engaged (simplified weapon cycle)
            }
        }
        for e in &mut self.enemies {
            e.cooldown = e.cooldown.saturating_sub(1);
        }
        damage_taken
    }
}

impl MultiAgentEnv for SmacLite {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let meta = self.reset_soa();
        self.materialize(meta)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let meta = self.step_soa(&ActionsRef::from_actions(actions));
        self.materialize(meta)
    }

    fn writes_soa(&self) -> bool {
        true
    }

    fn has_legal(&self) -> bool {
        true
    }

    fn reset_soa(&mut self) -> StepMeta {
        self.t = 0;
        self.done = false;
        self.last_reward = 0.0;
        self.spawn();
        StepMeta { step_type: StepType::First, discount: 1.0 }
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        assert!(!self.done, "step() after episode end");
        let acts = actions.as_discrete();
        self.t += 1;
        let mut reward_raw = 0.0;

        // --- ally actions ---
        for i in 0..self.n {
            if !self.allies[i].alive() {
                continue;
            }
            let a = acts[i] as usize;
            match a {
                ACT_NOOP | ACT_STOP => {}
                m if (ACT_MOVE_N..ACT_MOVE_N + 4).contains(&m) => {
                    let (dx, dy) = match m - ACT_MOVE_N {
                        0 => (0.0, MOVE_STEP),
                        1 => (0.0, -MOVE_STEP),
                        2 => (MOVE_STEP, 0.0),
                        _ => (-MOVE_STEP, 0.0),
                    };
                    self.allies[i].x = (self.allies[i].x + dx).clamp(0.0, MAP);
                    self.allies[i].y = (self.allies[i].y + dy).clamp(0.0, MAP);
                }
                atk if atk >= ACT_ATTACK_0 && atk < ACT_ATTACK_0 + self.n => {
                    let e = atk - ACT_ATTACK_0;
                    let enemy_alive = self.enemies[e].alive();
                    let in_range = self.allies[i].dist(&self.enemies[e])
                        <= SHOOT_RANGE;
                    if enemy_alive && in_range && self.allies[i].cooldown == 0 {
                        let dmg = DAMAGE.min(self.enemies[e].health);
                        self.enemies[e].health -= dmg;
                        reward_raw += dmg;
                        if !self.enemies[e].alive() {
                            reward_raw += KILL_BONUS;
                        }
                        self.allies[i].cooldown = COOLDOWN;
                    }
                }
                _ => {} // illegal action index: treated as stop
            }
        }
        for a in &mut self.allies {
            a.cooldown = a.cooldown.saturating_sub(1);
        }

        // --- enemy heuristic ---
        self.enemy_turn();

        let allies_alive = self.allies.iter().any(|u| u.alive());
        let enemies_alive = self.enemies.iter().any(|u| u.alive());
        let won = !enemies_alive;
        if won {
            reward_raw += WIN_BONUS;
        }
        let terminal = won || !allies_alive;
        let truncated = !terminal && self.t >= self.spec.episode_limit;
        self.done = terminal || truncated;

        self.last_reward = reward_raw / self.max_reward * REWARD_CAP;
        let step_type = if self.done { StepType::Last } else { StepType::Mid };
        let discount = if terminal { 0.0 } else { 1.0 };
        StepMeta { step_type, discount }
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        let od = self.spec.obs_dim;
        for i in 0..self.n {
            let me = &self.allies[i];
            let o = &mut out[i * od..(i + 1) * od];
            if !me.alive() {
                o.fill(0.0);
                continue;
            }
            o[0] = me.health / MAX_HEALTH;
            o[1] = me.x / (MAP / 2.0) - 1.0;
            o[2] = me.y / (MAP / 2.0) - 1.0;
            o[3] = me.cooldown as f32 / COOLDOWN.max(1) as f32;
            let mut k = 4;
            for (j, ally) in self.allies.iter().enumerate() {
                if j != i {
                    o[k..k + 5].copy_from_slice(&Self::unit_feats(
                        me,
                        ally,
                        SIGHT_RANGE,
                    ));
                    k += 5;
                }
            }
            for enemy in &self.enemies {
                o[k..k + 5].copy_from_slice(&Self::unit_feats(
                    me,
                    enemy,
                    SIGHT_RANGE,
                ));
                k += 5;
            }
            o[k] = 1.0;
            debug_assert_eq!(k + 1, od);
        }
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        out.fill(self.last_reward);
    }

    fn write_state(&mut self, out: &mut [f32]) {
        // mixer state = stacked observations (state_dim == n * obs_dim)
        self.write_obs(out);
    }

    fn write_legal(&mut self, out: &mut [f32]) {
        let na = 6 + self.n;
        for i in 0..self.n {
            let me = &self.allies[i];
            let l = &mut out[i * na..(i + 1) * na];
            l.fill(0.0);
            if !me.alive() {
                l[ACT_NOOP] = 1.0;
                continue;
            }
            l[ACT_STOP] = 1.0;
            for k in 0..4 {
                l[ACT_MOVE_N + k] = 1.0;
            }
            for (e, enemy) in self.enemies.iter().enumerate() {
                l[ACT_ATTACK_0 + e] = (enemy.alive()
                    && me.dist(enemy) <= SHOOT_RANGE)
                    as u8 as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stop_all(n: usize) -> Actions {
        Actions::Discrete(vec![ACT_STOP as i32; n])
    }

    #[test]
    fn spec_shapes() {
        let env = SmacLite::new_3m(0);
        assert_eq!(env.spec().obs_dim, 30);
        assert_eq!(env.spec().n_actions(), 9);
        assert_eq!(env.spec().state_dim, 90);
    }

    #[test]
    fn passive_team_eventually_loses() {
        let mut env = SmacLite::new_3m(1);
        let mut ts = env.reset();
        let mut total = 0.0;
        let mut steps = 0;
        while !ts.is_last() {
            ts = env.step(&stop_all(3));
            total += ts.rewards[0];
            steps += 1;
        }
        // passive allies deal no damage -> no positive reward
        assert!(total <= 1e-6, "passive reward {total}");
        assert!(steps <= 60);
        // all allies dead -> enemies focused them down
        assert!(env.allies.iter().all(|u| !u.alive()));
    }

    #[test]
    fn attacking_earns_reward_and_can_win() {
        // teleport-free win: scripted focus fire from in-range start
        let mut env = SmacLite::new_3m(2);
        let mut ts = env.reset();
        // move east until enemies are in range, then focus enemy 0,1,2
        let mut total = 0.0;
        let mut wins = false;
        for _ in 0..60 {
            if ts.is_last() {
                break;
            }
            let legal = ts.legal_actions.as_ref().unwrap();
            let acts: Vec<i32> = (0..3)
                .map(|i| {
                    // attack lowest-index attackable enemy, else move east
                    for e in 0..3 {
                        if legal[i][ACT_ATTACK_0 + e] {
                            return (ACT_ATTACK_0 + e) as i32;
                        }
                    }
                    if legal[i][ACT_MOVE_N + 2] {
                        (ACT_MOVE_N + 2) as i32
                    } else {
                        ACT_NOOP as i32
                    }
                })
                .collect();
            ts = env.step(&Actions::Discrete(acts));
            total += ts.rewards[0];
            if !env.enemies.iter().any(|u| u.alive()) {
                wins = true;
            }
        }
        assert!(total > 0.0, "attacking must earn shaped reward");
        // the scripted policy reliably beats the heuristic on this seed
        assert!(wins, "scripted focus fire should win");
        assert!(total <= REWARD_CAP + 1e-4);
    }

    #[test]
    fn dead_agents_have_zero_obs_and_only_noop() {
        let mut env = SmacLite::new_3m(3);
        env.reset();
        env.allies[1].health = 0.0;
        let na = env.spec().n_actions();
        let mut legal = vec![0.0f32; 3 * na];
        env.write_legal(&mut legal);
        assert_eq!(legal[na + ACT_NOOP], 1.0);
        assert_eq!(legal[na + ACT_STOP], 0.0);
        let od = env.spec().obs_dim;
        let mut obs = vec![1.0f32; 3 * od];
        env.write_obs(&mut obs);
        assert!(obs[od..2 * od].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn reward_normalised_below_cap() {
        let mut env = SmacLite::new_3m(4);
        let mut rng = Rng::new(9);
        for _ in 0..10 {
            let (ret, _) = crate::env::random_episode(&mut env, &mut rng);
            assert!(ret <= REWARD_CAP + 1e-4);
        }
    }
}
