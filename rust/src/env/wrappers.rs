//! Environment wrappers — Mava's composable observation modules.
//!
//! * [`FingerprintWrapper`] — replay-stabilisation fingerprints (Foerster
//!   et al., 2017c): appends `[epsilon, training-progress]` to every
//!   observation (and the global state) so the replay distribution is
//!   identifiable, mitigating MARL non-stationarity. Mava exposes this as
//!   `stabilising.FingerPrintStabalisation(architecture)`; here it is an
//!   env wrapper feeding the `smac3m_fp` artifact preset.
//! * [`AgentIdWrapper`] — appends a one-hot agent id (used with weight
//!   sharing).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::core::{Actions, EnvSpec, TimeStep};
use crate::env::MultiAgentEnv;

/// Shared, mutable fingerprint the executor updates as training proceeds.
#[derive(Clone, Default)]
pub struct Fingerprint {
    // f32 bits stored atomically so executor threads can update lock-free
    eps: Arc<AtomicU32>,
    progress: Arc<AtomicU32>,
}

impl Fingerprint {
    /// A fingerprint initialised to `(eps, progress)`.
    pub fn new(eps: f32, progress: f32) -> Self {
        let fp = Fingerprint::default();
        fp.set(eps, progress);
        fp
    }

    /// Publish new fingerprint values (executor side, lock-free).
    pub fn set(&self, eps: f32, progress: f32) {
        self.eps.store(eps.to_bits(), Ordering::Relaxed);
        self.progress.store(progress.to_bits(), Ordering::Relaxed);
    }

    /// Read the current `(eps, progress)` pair.
    pub fn get(&self) -> (f32, f32) {
        (
            f32::from_bits(self.eps.load(Ordering::Relaxed)),
            f32::from_bits(self.progress.load(Ordering::Relaxed)),
        )
    }
}

/// Appends the `[eps, progress]` fingerprint to every observation
/// (and rebuilds the global state accordingly).
pub struct FingerprintWrapper<E> {
    inner: E,
    spec: EnvSpec,
    /// Shared handle the executor updates as training proceeds.
    pub fingerprint: Fingerprint,
}

impl<E: MultiAgentEnv> FingerprintWrapper<E> {
    /// Wrap `inner`, extending its spec by the fingerprint dims.
    pub fn new(inner: E, fingerprint: Fingerprint) -> Self {
        let mut spec = inner.spec().clone();
        spec.obs_dim += 2;
        spec.state_dim = if spec.state_dim > 0 {
            spec.state_dim + 2 * spec.n_agents
        } else {
            0
        };
        FingerprintWrapper { inner, spec, fingerprint }
    }

    fn augment(&self, mut ts: TimeStep) -> TimeStep {
        let (eps, prog) = self.fingerprint.get();
        for obs in &mut ts.observations {
            obs.push(eps);
            obs.push(prog);
        }
        if !ts.state.is_empty() {
            ts.state = ts.observations.concat();
        }
        ts
    }
}

impl<E: MultiAgentEnv> MultiAgentEnv for FingerprintWrapper<E> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let ts = self.inner.reset();
        self.augment(ts)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let ts = self.inner.step(actions);
        self.augment(ts)
    }
}

/// Appends a one-hot agent id to each observation.
pub struct AgentIdWrapper<E> {
    inner: E,
    spec: EnvSpec,
}

impl<E: MultiAgentEnv> AgentIdWrapper<E> {
    /// Wrap `inner`, extending its spec by the one-hot id dims.
    pub fn new(inner: E) -> Self {
        let mut spec = inner.spec().clone();
        let n = spec.n_agents;
        spec.obs_dim += n;
        spec.state_dim = if spec.state_dim > 0 {
            spec.state_dim + n * n
        } else {
            0
        };
        AgentIdWrapper { inner, spec }
    }

    fn augment(&self, mut ts: TimeStep) -> TimeStep {
        let n = self.spec.n_agents;
        for (i, obs) in ts.observations.iter_mut().enumerate() {
            for j in 0..n {
                obs.push((i == j) as u8 as f32);
            }
        }
        if !ts.state.is_empty() {
            ts.state = ts.observations.concat();
        }
        ts
    }
}

impl<E: MultiAgentEnv> MultiAgentEnv for AgentIdWrapper<E> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let ts = self.inner.reset();
        self.augment(ts)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let ts = self.inner.step(actions);
        self.augment(ts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::smac_lite::SmacLite;

    #[test]
    fn fingerprint_extends_obs_and_state() {
        let fp = Fingerprint::new(0.3, 0.5);
        let mut env = FingerprintWrapper::new(SmacLite::new_3m(0), fp.clone());
        assert_eq!(env.spec().obs_dim, 32);
        assert_eq!(env.spec().state_dim, 96);
        let ts = env.reset();
        for o in &ts.observations {
            assert_eq!(o.len(), 32);
            assert_eq!(o[30], 0.3);
            assert_eq!(o[31], 0.5);
        }
        assert_eq!(ts.state.len(), 96);
        // fingerprint updates are visible on the next step
        fp.set(0.1, 0.9);
        let ts = env.step(&Actions::Discrete(vec![1, 1, 1]));
        assert_eq!(ts.observations[0][30], 0.1);
        assert_eq!(ts.observations[0][31], 0.9);
    }

    #[test]
    fn agent_id_onehot_appended() {
        let mut env = AgentIdWrapper::new(SmacLite::new_3m(1));
        assert_eq!(env.spec().obs_dim, 33);
        let ts = env.reset();
        assert_eq!(&ts.observations[1][30..33], &[0.0, 1.0, 0.0]);
    }
}
