//! Environment wrappers — Mava's composable observation modules.
//!
//! * [`FingerprintWrapper`] — replay-stabilisation fingerprints (Foerster
//!   et al., 2017c): appends `[epsilon, training-progress]` to every
//!   observation (and the global state) so the replay distribution is
//!   identifiable, mitigating MARL non-stationarity. Mava exposes this as
//!   `stabilising.FingerPrintStabalisation(architecture)`; here it is an
//!   env wrapper feeding the `smac3m_fp` artifact preset.
//! * [`AgentIdWrapper`] — appends a one-hot agent id (used with weight
//!   sharing).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::core::{Actions, ActionsRef, EnvSpec, StepMeta, TimeStep};
use crate::env::MultiAgentEnv;

/// Shared, mutable fingerprint the executor updates as training proceeds.
#[derive(Clone, Default)]
pub struct Fingerprint {
    // f32 bits stored atomically so executor threads can update lock-free
    eps: Arc<AtomicU32>,
    progress: Arc<AtomicU32>,
}

impl Fingerprint {
    /// A fingerprint initialised to `(eps, progress)`.
    pub fn new(eps: f32, progress: f32) -> Self {
        let fp = Fingerprint::default();
        fp.set(eps, progress);
        fp
    }

    /// Publish new fingerprint values (executor side, lock-free).
    pub fn set(&self, eps: f32, progress: f32) {
        self.eps.store(eps.to_bits(), Ordering::Relaxed);
        self.progress.store(progress.to_bits(), Ordering::Relaxed);
    }

    /// Read the current `(eps, progress)` pair.
    pub fn get(&self) -> (f32, f32) {
        (
            f32::from_bits(self.eps.load(Ordering::Relaxed)),
            f32::from_bits(self.progress.load(Ordering::Relaxed)),
        )
    }
}

/// Appends the `[eps, progress]` fingerprint to every observation
/// (and rebuilds the global state accordingly).
pub struct FingerprintWrapper<E> {
    inner: E,
    spec: EnvSpec,
    /// Shared handle the executor updates as training proceeds.
    pub fingerprint: Fingerprint,
    /// Reused `[N * inner_obs_dim]` staging buffer for the SoA strided
    /// scatter (allocated lazily on the first write).
    scratch: Vec<f32>,
}

impl<E: MultiAgentEnv> FingerprintWrapper<E> {
    /// Wrap `inner`, extending its spec by the fingerprint dims.
    pub fn new(inner: E, fingerprint: Fingerprint) -> Self {
        let mut spec = inner.spec().clone();
        spec.obs_dim += 2;
        spec.state_dim = if spec.state_dim > 0 {
            spec.state_dim + 2 * spec.n_agents
        } else {
            0
        };
        FingerprintWrapper { inner, spec, fingerprint, scratch: Vec::new() }
    }

    fn augment(&self, mut ts: TimeStep) -> TimeStep {
        let (eps, prog) = self.fingerprint.get();
        for obs in &mut ts.observations {
            obs.push(eps);
            obs.push(prog);
        }
        if !ts.state.is_empty() {
            ts.state = ts.observations.concat();
        }
        ts
    }
}

impl<E: MultiAgentEnv> MultiAgentEnv for FingerprintWrapper<E> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let ts = self.inner.reset();
        self.augment(ts)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let ts = self.inner.step(actions);
        self.augment(ts)
    }

    fn writes_soa(&self) -> bool {
        self.inner.writes_soa()
    }

    fn reset_soa(&mut self) -> StepMeta {
        self.inner.reset_soa()
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        self.inner.step_soa(actions)
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        let n = self.spec.n_agents;
        let o = self.spec.obs_dim;
        let oi = o - 2;
        self.scratch.resize(n * oi, 0.0);
        self.inner.write_obs(&mut self.scratch);
        let (eps, prog) = self.fingerprint.get();
        for i in 0..n {
            let dst = &mut out[i * o..(i + 1) * o];
            dst[..oi].copy_from_slice(&self.scratch[i * oi..(i + 1) * oi]);
            dst[oi] = eps;
            dst[oi + 1] = prog;
        }
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        self.inner.write_rewards(out);
    }

    fn write_state(&mut self, out: &mut [f32]) {
        // like `augment`: the fingerprinted state is the stacked
        // augmented observations
        debug_assert_eq!(out.len(), self.spec.n_agents * self.spec.obs_dim);
        self.write_obs(out);
    }

    fn has_legal(&self) -> bool {
        self.inner.has_legal()
    }

    fn write_legal(&mut self, out: &mut [f32]) {
        self.inner.write_legal(out);
    }
}

/// Appends a one-hot agent id to each observation.
pub struct AgentIdWrapper<E> {
    inner: E,
    spec: EnvSpec,
    /// Reused `[N * inner_obs_dim]` staging buffer (see
    /// [`FingerprintWrapper`]).
    scratch: Vec<f32>,
}

impl<E: MultiAgentEnv> AgentIdWrapper<E> {
    /// Wrap `inner`, extending its spec by the one-hot id dims.
    pub fn new(inner: E) -> Self {
        let mut spec = inner.spec().clone();
        let n = spec.n_agents;
        spec.obs_dim += n;
        spec.state_dim = if spec.state_dim > 0 {
            spec.state_dim + n * n
        } else {
            0
        };
        AgentIdWrapper { inner, spec, scratch: Vec::new() }
    }

    fn augment(&self, mut ts: TimeStep) -> TimeStep {
        let n = self.spec.n_agents;
        for (i, obs) in ts.observations.iter_mut().enumerate() {
            for j in 0..n {
                obs.push((i == j) as u8 as f32);
            }
        }
        if !ts.state.is_empty() {
            ts.state = ts.observations.concat();
        }
        ts
    }
}

impl<E: MultiAgentEnv> MultiAgentEnv for AgentIdWrapper<E> {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let ts = self.inner.reset();
        self.augment(ts)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let ts = self.inner.step(actions);
        self.augment(ts)
    }

    fn writes_soa(&self) -> bool {
        self.inner.writes_soa()
    }

    fn reset_soa(&mut self) -> StepMeta {
        self.inner.reset_soa()
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        self.inner.step_soa(actions)
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        let n = self.spec.n_agents;
        let o = self.spec.obs_dim;
        let oi = o - n;
        self.scratch.resize(n * oi, 0.0);
        self.inner.write_obs(&mut self.scratch);
        for i in 0..n {
            let dst = &mut out[i * o..(i + 1) * o];
            dst[..oi].copy_from_slice(&self.scratch[i * oi..(i + 1) * oi]);
            for j in 0..n {
                dst[oi + j] = (i == j) as u8 as f32;
            }
        }
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        self.inner.write_rewards(out);
    }

    fn write_state(&mut self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.spec.n_agents * self.spec.obs_dim);
        self.write_obs(out);
    }

    fn has_legal(&self) -> bool {
        self.inner.has_legal()
    }

    fn write_legal(&mut self, out: &mut [f32]) {
        self.inner.write_legal(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::smac_lite::SmacLite;

    #[test]
    fn fingerprint_extends_obs_and_state() {
        let fp = Fingerprint::new(0.3, 0.5);
        let mut env = FingerprintWrapper::new(SmacLite::new_3m(0), fp.clone());
        assert_eq!(env.spec().obs_dim, 32);
        assert_eq!(env.spec().state_dim, 96);
        let ts = env.reset();
        for o in &ts.observations {
            assert_eq!(o.len(), 32);
            assert_eq!(o[30], 0.3);
            assert_eq!(o[31], 0.5);
        }
        assert_eq!(ts.state.len(), 96);
        // fingerprint updates are visible on the next step
        fp.set(0.1, 0.9);
        let ts = env.step(&Actions::Discrete(vec![1, 1, 1]));
        assert_eq!(ts.observations[0][30], 0.1);
        assert_eq!(ts.observations[0][31], 0.9);
    }

    /// The wrapper's SoA write hooks must produce exactly what the
    /// timestep path produces (the `_fp` preset rides the hot path).
    #[test]
    fn fingerprint_soa_matches_timestep_path() {
        let mut legacy = FingerprintWrapper::new(
            SmacLite::new_3m(7),
            Fingerprint::new(0.3, 0.5),
        );
        let mut soa = FingerprintWrapper::new(
            SmacLite::new_3m(7),
            Fingerprint::new(0.3, 0.5),
        );
        assert!(soa.writes_soa());
        assert!(soa.has_legal());
        let (n, o, s, na) = {
            let sp = soa.spec();
            (sp.n_agents, sp.obs_dim, sp.state_dim, sp.n_actions())
        };
        let ts = legacy.reset();
        soa.reset_soa();
        let mut obs = vec![0.0f32; n * o];
        soa.write_obs(&mut obs);
        assert_eq!(ts.observations.concat(), obs);
        let mut state = vec![0.0f32; s];
        soa.write_state(&mut state);
        assert_eq!(ts.state, state);
        let mut rewards = vec![1.0f32; n];
        soa.write_rewards(&mut rewards);
        assert_eq!(ts.rewards, rewards);
        let mut legal = vec![0.0f32; n * na];
        soa.write_legal(&mut legal);
        let want: Vec<f32> = ts
            .legal_actions
            .as_ref()
            .unwrap()
            .iter()
            .flatten()
            .map(|&b| b as u8 as f32)
            .collect();
        assert_eq!(want, legal);
    }

    #[test]
    fn agent_id_onehot_appended() {
        let mut env = AgentIdWrapper::new(SmacLite::new_3m(1));
        assert_eq!(env.spec().obs_dim, 33);
        let ts = env.reset();
        assert_eq!(&ts.observations[1][30..33], &[0.0, 1.0, 0.0]);
    }
}
