//! Multi-agent environment suite.
//!
//! The paper evaluates on PettingZoo MPE, SMAC, the switch riddle and
//! Multi-Walker. None of those substrates are available here (SC2 is a
//! closed binary; PettingZoo is python), so each is reimplemented as a
//! Rust simulator that preserves the structure the corresponding
//! experiment exercises — see DESIGN.md §3 for the substitution table.
//!
//! [`vec_env::VecEnv`] batches `num_envs_per_executor` instances of any
//! of these environments behind stacked `[B, N, obs]` observations — the
//! executor-side half of the vectorized hot path (DESIGN.md §6).

#![warn(missing_docs)]

pub mod matrix;
pub mod mpe;
pub mod multiwalker;
pub mod smac_lite;
pub mod switch;
pub mod vec_env;
pub mod wrappers;

pub use vec_env::{VecEnv, VecStep};

use crate::core::{Actions, EnvSpec, TimeStep};
use anyhow::{bail, Result};

/// The Mava / dm_env multi-agent environment interface (paper Block 1).
pub trait MultiAgentEnv: Send {
    fn spec(&self) -> &EnvSpec;
    /// Start a new episode; returns the `First` timestep.
    fn reset(&mut self) -> TimeStep;
    /// Apply the joint action; returns the next timestep.
    fn step(&mut self, actions: &Actions) -> TimeStep;
}

/// Construct an environment by preset env-name (manifest `env` field).
pub fn make_env(name: &str, seed: u64) -> Result<Box<dyn MultiAgentEnv>> {
    Ok(match name {
        "matrix" => Box::new(matrix::ClimbingGame::new(seed)),
        "switch" => Box::new(switch::SwitchGame::new(3, seed)),
        "smac_lite" => Box::new(smac_lite::SmacLite::new_3m(seed)),
        "mpe_spread" => Box::new(mpe::spread::Spread::new(3, seed)),
        "mpe_speaker_listener" => {
            Box::new(mpe::speaker_listener::SpeakerListener::new(seed))
        }
        "multiwalker" => Box::new(multiwalker::MultiWalker::new(3, seed)),
        other => bail!("unknown environment {other:?}"),
    })
}

/// Run one full episode with uniformly random actions (test helper).
#[cfg(test)]
pub(crate) fn random_episode(
    env: &mut dyn MultiAgentEnv,
    rng: &mut crate::rng::Rng,
) -> (f32, usize) {
    use crate::core::ActionSpec;
    let spec = env.spec().clone();
    let mut ts = env.reset();
    let mut ret = 0.0;
    let mut steps = 0;
    while !ts.is_last() {
        let actions = match spec.action {
            ActionSpec::Discrete { n } => {
                let legal = ts.legal_actions.clone();
                let a = (0..spec.n_agents)
                    .map(|i| {
                        if let Some(l) = &legal {
                            // sample among legal actions
                            let ids: Vec<usize> = (0..n)
                                .filter(|&k| l[i][k])
                                .collect();
                            ids[rng.below(ids.len())] as i32
                        } else {
                            rng.below(n) as i32
                        }
                    })
                    .collect();
                Actions::Discrete(a)
            }
            ActionSpec::Continuous { dim } => Actions::Continuous(
                (0..spec.n_agents)
                    .map(|_| (0..dim).map(|_| rng.range_f32(-1.0, 1.0)).collect())
                    .collect(),
            ),
        };
        ts = env.step(&actions);
        ret += ts.team_reward() / spec.n_agents as f32;
        steps += 1;
        assert_eq!(ts.observations.len(), spec.n_agents);
        for o in &ts.observations {
            assert_eq!(o.len(), spec.obs_dim);
            assert!(o.iter().all(|x| x.is_finite()));
        }
        assert_eq!(ts.state.len(), spec.state_dim);
        assert!(steps <= spec.episode_limit + 1, "episode never terminated");
    }
    (ret, steps)
}
