//! Multi-agent environment suite.
//!
//! The paper evaluates on PettingZoo MPE, SMAC, the switch riddle and
//! Multi-Walker. None of those substrates are available here (SC2 is a
//! closed binary; PettingZoo is python), so each is reimplemented as a
//! Rust simulator that preserves the structure the corresponding
//! experiment exercises — see DESIGN.md §3 for the substitution table.
//!
//! [`vec_env::VecEnv`] batches `num_envs_per_executor` instances of any
//! of these environments behind stacked `[B, N, obs]` observations — the
//! executor-side half of the vectorized hot path (DESIGN.md §6).

#![warn(missing_docs)]

pub mod matrix;
pub mod mpe;
pub mod multiwalker;
pub mod smac_lite;
pub mod switch;
pub mod vec_env;
pub mod wrappers;

pub use vec_env::{ActionBuf, VecEnv, VecStep, VecStepBuf};

use crate::core::{Actions, ActionsRef, EnvSpec, StepMeta, TimeStep};
use anyhow::{bail, Result};

/// The Mava / dm_env multi-agent environment interface (paper Block 1).
///
/// Besides the classic allocating `reset`/`step` → [`TimeStep`] API,
/// the trait carries the struct-of-arrays hot-path hooks of
/// DESIGN.md §6: an environment that opts in (`writes_soa() == true`)
/// advances with [`MultiAgentEnv::step_soa`] and then *writes* its
/// observations / rewards / state / legal mask directly into caller-
/// provided slices — rows of a [`VecStepBuf`] — so a vector step
/// performs zero heap allocations. Environments that do not opt in
/// keep working everywhere: [`VecEnv`] bridges them through the
/// timestep API (allocating) automatically.
pub trait MultiAgentEnv: Send {
    fn spec(&self) -> &EnvSpec;
    /// Start a new episode; returns the `First` timestep.
    fn reset(&mut self) -> TimeStep;
    /// Apply the joint action; returns the next timestep.
    fn step(&mut self, actions: &Actions) -> TimeStep;

    /// True when this environment implements the allocation-free SoA
    /// write hooks below. The defaults of those hooks panic, so only
    /// override them together with this flag.
    fn writes_soa(&self) -> bool {
        false
    }

    /// Start a new episode WITHOUT materialising a [`TimeStep`]; the
    /// produced tensors are read back through the `write_*` hooks.
    fn reset_soa(&mut self) -> StepMeta {
        unimplemented!("reset_soa: writes_soa() is false for this env")
    }

    /// Advance one step WITHOUT materialising a [`TimeStep`]; scalar
    /// results return by value, tensors via the `write_*` hooks.
    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        let _ = actions;
        unimplemented!("step_soa: writes_soa() is false for this env")
    }

    /// Write the current stacked per-agent observations into a
    /// `[N*obs_dim]` slice (agent `i` at `out[i*obs_dim..]`).
    fn write_obs(&mut self, out: &mut [f32]) {
        let _ = out;
        unimplemented!("write_obs: writes_soa() is false for this env")
    }

    /// Write the current per-agent rewards into a `[N]` slice
    /// (all-zero right after a reset).
    fn write_rewards(&mut self, out: &mut [f32]) {
        let _ = out;
        unimplemented!("write_rewards: writes_soa() is false for this env")
    }

    /// Write the current global state into a `[state_dim]` slice.
    /// Never called when `state_dim == 0`.
    fn write_state(&mut self, out: &mut [f32]) {
        let _ = out;
        unimplemented!("write_state: writes_soa() is false for this env")
    }

    /// True when this environment produces per-agent legal-action
    /// masks. Environments that do must override this alongside
    /// [`MultiAgentEnv::write_legal`] so the SoA pipeline allocates a
    /// mask plane for them.
    fn has_legal(&self) -> bool {
        false
    }

    /// Write the current legal-action mask into a `[N*n_actions]`
    /// slice (1.0 legal, 0.0 illegal; agent `i` at
    /// `out[i*n_actions..]`). Only called when `has_legal()`.
    fn write_legal(&mut self, out: &mut [f32]) {
        let _ = out;
        unimplemented!("write_legal: has_legal() is false for this env")
    }

    /// Build a [`TimeStep`] from the current post-step state via the
    /// SoA write hooks (provided; allocates). SoA environments
    /// implement `reset`/`step` as `*_soa` + this, so both APIs share
    /// one stepping path.
    fn materialize(&mut self, meta: StepMeta) -> TimeStep {
        debug_assert!(self.writes_soa());
        let (n, o, s, a, legal) = {
            let spec = self.spec();
            (
                spec.n_agents,
                spec.obs_dim,
                spec.state_dim,
                spec.n_actions(),
                self.has_legal(),
            )
        };
        let mut flat = vec![0.0f32; n * o];
        self.write_obs(&mut flat);
        let observations: Vec<Vec<f32>> =
            flat.chunks_exact(o.max(1)).map(|c| c.to_vec()).collect();
        let mut rewards = vec![0.0f32; n];
        self.write_rewards(&mut rewards);
        let mut state = vec![0.0f32; s];
        if s > 0 {
            self.write_state(&mut state);
        }
        let legal_actions = if legal {
            let mut mask = vec![0.0f32; n * a];
            self.write_legal(&mut mask);
            Some(
                mask.chunks_exact(a.max(1))
                    .map(|c| c.iter().map(|&x| x > 0.5).collect())
                    .collect(),
            )
        } else {
            None
        };
        TimeStep {
            step_type: meta.step_type,
            observations,
            rewards,
            discount: meta.discount,
            state,
            legal_actions,
        }
    }
}

// A boxed environment is an environment: every method — the SoA hooks
// in particular — must forward through the vtable, otherwise a default
// impl would shadow the inner override and silently disable the
// allocation-free path for wrapped envs.
impl MultiAgentEnv for Box<dyn MultiAgentEnv> {
    fn spec(&self) -> &EnvSpec {
        (**self).spec()
    }

    fn reset(&mut self) -> TimeStep {
        (**self).reset()
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        (**self).step(actions)
    }

    fn writes_soa(&self) -> bool {
        (**self).writes_soa()
    }

    fn reset_soa(&mut self) -> StepMeta {
        (**self).reset_soa()
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        (**self).step_soa(actions)
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        (**self).write_obs(out)
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        (**self).write_rewards(out)
    }

    fn write_state(&mut self, out: &mut [f32]) {
        (**self).write_state(out)
    }

    fn has_legal(&self) -> bool {
        (**self).has_legal()
    }

    fn write_legal(&mut self, out: &mut [f32]) {
        (**self).write_legal(out)
    }

    fn materialize(&mut self, meta: StepMeta) -> TimeStep {
        (**self).materialize(meta)
    }
}

/// Construct an environment by preset env-name (manifest `env` field).
pub fn make_env(name: &str, seed: u64) -> Result<Box<dyn MultiAgentEnv>> {
    Ok(match name {
        "matrix" => Box::new(matrix::ClimbingGame::new(seed)),
        "switch" => Box::new(switch::SwitchGame::new(3, seed)),
        "smac_lite" => Box::new(smac_lite::SmacLite::new_3m(seed)),
        "mpe_spread" => Box::new(mpe::spread::Spread::new(3, seed)),
        "mpe_speaker_listener" => {
            Box::new(mpe::speaker_listener::SpeakerListener::new(seed))
        }
        "multiwalker" => Box::new(multiwalker::MultiWalker::new(3, seed)),
        other => bail!("unknown environment {other:?}"),
    })
}

/// Run one full episode with uniformly random actions (test helper).
#[cfg(test)]
pub(crate) fn random_episode(
    env: &mut dyn MultiAgentEnv,
    rng: &mut crate::rng::Rng,
) -> (f32, usize) {
    use crate::core::ActionSpec;
    let spec = env.spec().clone();
    let mut ts = env.reset();
    let mut ret = 0.0;
    let mut steps = 0;
    while !ts.is_last() {
        let actions = match spec.action {
            ActionSpec::Discrete { n } => {
                let legal = ts.legal_actions.clone();
                let a = (0..spec.n_agents)
                    .map(|i| {
                        if let Some(l) = &legal {
                            // sample among legal actions
                            let ids: Vec<usize> = (0..n)
                                .filter(|&k| l[i][k])
                                .collect();
                            ids[rng.below(ids.len())] as i32
                        } else {
                            rng.below(n) as i32
                        }
                    })
                    .collect();
                Actions::Discrete(a)
            }
            ActionSpec::Continuous { dim } => Actions::Continuous(
                (0..spec.n_agents)
                    .map(|_| (0..dim).map(|_| rng.range_f32(-1.0, 1.0)).collect())
                    .collect(),
            ),
        };
        ts = env.step(&actions);
        ret += ts.team_reward() / spec.n_agents as f32;
        steps += 1;
        assert_eq!(ts.observations.len(), spec.n_agents);
        for o in &ts.observations {
            assert_eq!(o.len(), spec.obs_dim);
            assert!(o.iter().all(|x| x.is_finite()));
        }
        assert_eq!(ts.state.len(), spec.state_dim);
        assert!(steps <= spec.episode_limit + 1, "episode never terminated");
    }
    (ret, steps)
}
