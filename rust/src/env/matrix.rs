//! Repeated matrix games — tiny cooperative benchmarks used by the unit
//! and integration tests (and as the `matrix2` artifact preset).
//!
//! The climbing game (Claus & Boutilier, 1998) is the classic coordination
//! testbed: two agents, payoff matrix
//!
//! ```text
//!            a2=0    a2=1   a2=2
//!   a1=0      11     -30      0
//!   a1=1     -30       7      6
//!   a1=2       0       0      5
//! ```
//!
//! with a deceptive optimum at (0,0) surrounded by punishing
//! miscoordination. Episodes are `episode_limit` repeats; observations
//! encode the previous joint action so that recurrent-free Q-learners can
//! still condition on history.

use crate::core::{
    ActionSpec, Actions, ActionsRef, EnvSpec, StepMeta, StepType, TimeStep,
};
use crate::env::MultiAgentEnv;
use crate::rng::Rng;

/// The climbing-game payoff matrix (deceptive optimum at (0,0)).
pub const CLIMBING: [[f32; 3]; 3] =
    [[11.0, -30.0, 0.0], [-30.0, 7.0, 6.0], [0.0, 0.0, 5.0]];

/// The penalty-game payoff matrix (miscoordination penalised).
pub const PENALTY: [[f32; 3]; 3] =
    [[10.0, 0.0, -10.0], [0.0, 2.0, 0.0], [-10.0, 0.0, 10.0]];

/// A repeated 2-agent 3-action matrix game with history-encoding
/// observations.
pub struct ClimbingGame {
    spec: EnvSpec,
    payoff: [[f32; 3]; 3],
    t: usize,
    last: [i32; 2],
    last_reward: f32,
    _rng: Rng,
}

impl ClimbingGame {
    /// The climbing game (default test payoff).
    pub fn new(seed: u64) -> Self {
        Self::with_payoff(CLIMBING, seed)
    }

    /// The penalty game variant.
    pub fn penalty(seed: u64) -> Self {
        Self::with_payoff(PENALTY, seed)
    }

    /// A repeated game over an arbitrary 3x3 payoff matrix.
    pub fn with_payoff(payoff: [[f32; 3]; 3], seed: u64) -> Self {
        ClimbingGame {
            spec: EnvSpec {
                name: "matrix".into(),
                n_agents: 2,
                obs_dim: 4,
                action: ActionSpec::Discrete { n: 3 },
                state_dim: 8,
                episode_limit: 5,
            },
            payoff,
            t: 0,
            last: [-1, -1],
            last_reward: 0.0,
            _rng: Rng::new(seed),
        }
    }
}

impl MultiAgentEnv for ClimbingGame {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let meta = self.reset_soa();
        self.materialize(meta)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let meta = self.step_soa(&ActionsRef::from_actions(actions));
        self.materialize(meta)
    }

    fn writes_soa(&self) -> bool {
        true
    }

    fn reset_soa(&mut self) -> StepMeta {
        self.t = 0;
        self.last = [-1, -1];
        self.last_reward = 0.0;
        StepMeta { step_type: StepType::First, discount: 1.0 }
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        let a = actions.as_discrete();
        self.last_reward = self.payoff[a[0] as usize][a[1] as usize];
        self.last = [a[0], a[1]];
        self.t += 1;
        let last = self.t >= self.spec.episode_limit;
        StepMeta {
            step_type: if last { StepType::Last } else { StepType::Mid },
            // repeats truncate, never terminate
            discount: 1.0,
        }
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        let tfrac = self.t as f32 / self.spec.episode_limit as f32;
        for i in 0..2 {
            let o = &mut out[i * 4..(i + 1) * 4];
            o[0] = 1.0;
            o[1] = tfrac;
            o[2] = (self.last[i] as f32 + 1.0) / 3.0;
            o[3] = (self.last[1 - i] as f32 + 1.0) / 3.0;
        }
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        out.fill(self.last_reward);
    }

    fn write_state(&mut self, out: &mut [f32]) {
        // state = stacked observations (state_dim == n_agents * obs_dim)
        self.write_obs(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_joint_action_pays_eleven() {
        let mut env = ClimbingGame::new(0);
        env.reset();
        let ts = env.step(&Actions::Discrete(vec![0, 0]));
        assert_eq!(ts.rewards, vec![11.0, 11.0]);
    }

    #[test]
    fn miscoordination_punished() {
        let mut env = ClimbingGame::new(0);
        env.reset();
        let ts = env.step(&Actions::Discrete(vec![0, 1]));
        assert_eq!(ts.rewards[0], -30.0);
    }

    #[test]
    fn episode_length() {
        let mut env = ClimbingGame::new(0);
        let mut ts = env.reset();
        let mut n = 0;
        while !ts.is_last() {
            ts = env.step(&Actions::Discrete(vec![2, 2]));
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn obs_encode_last_actions() {
        let mut env = ClimbingGame::new(0);
        env.reset();
        let ts = env.step(&Actions::Discrete(vec![1, 2]));
        // agent 0 sees own=1 -> (1+1)/3, other=2 -> (2+1)/3
        assert!((ts.observations[0][2] - 2.0 / 3.0).abs() < 1e-6);
        assert!((ts.observations[0][3] - 1.0).abs() < 1e-6);
        // agent 1 mirrored
        assert!((ts.observations[1][2] - 1.0).abs() < 1e-6);
        assert_eq!(ts.state.len(), 8);
    }

    #[test]
    fn random_play_runs() {
        let mut env = ClimbingGame::new(1);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            crate::env::random_episode(&mut env, &mut rng);
        }
    }
}
