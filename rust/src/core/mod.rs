//! Core multi-agent types: timesteps, specs, actions and host tensors.
//!
//! These mirror Mava's multi-agent extensions of the dm_env API: a
//! [`TimeStep`] carries per-agent observations and rewards (the paper's
//! "set of dictionaries indexed by agent ids" — here dense `Vec`s indexed
//! by agent position), a shared discount and the step type. The extra
//! `state` field carries the global state used by mixers / centralised
//! critics (SMAC-style), and `legal_actions` the per-agent action masks.

mod tensor;

pub use tensor::{Dtype, HostTensor};

/// Per-step scalar results of the SoA environment-stepping hooks
/// (everything a vector step produces that is not a tensor plane of the
/// batch buffer).
#[derive(Clone, Copy, Debug)]
pub struct StepMeta {
    /// dm_env step type of the produced step.
    pub step_type: StepType,
    /// Bootstrap discount (0.0 on terminal `Last` steps).
    pub discount: f32,
}

/// Index of an agent within a system (Mava: `"agent_0"` etc.).
pub type AgentId = usize;

/// dm_env step type: first / transition / last step of an episode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepType {
    First,
    Mid,
    Last,
}

/// A multi-agent environment transition (dm_env TimeStep, multi-agent).
#[derive(Clone, Debug)]
pub struct TimeStep {
    pub step_type: StepType,
    /// Per-agent observation vectors (padded to the spec's `obs_dim`).
    pub observations: Vec<Vec<f32>>,
    /// Per-agent rewards. On `First` steps these are zero.
    pub rewards: Vec<f32>,
    /// Shared discount: 1.0 mid-episode, 0.0 on terminal `Last` steps,
    /// 1.0 on truncation (time-limit) `Last` steps.
    pub discount: f32,
    /// Global environment state for mixers / centralised critics
    /// (empty when the preset does not use one).
    pub state: Vec<f32>,
    /// Per-agent legal-action masks (discrete envs only).
    pub legal_actions: Option<Vec<Vec<bool>>>,
}

impl TimeStep {
    pub fn is_last(&self) -> bool {
        self.step_type == StepType::Last
    }

    pub fn n_agents(&self) -> usize {
        self.observations.len()
    }

    /// Team (summed) reward.
    pub fn team_reward(&self) -> f32 {
        self.rewards.iter().sum()
    }
}

/// Joint action for all agents.
#[derive(Clone, Debug)]
pub enum Actions {
    Discrete(Vec<i32>),
    Continuous(Vec<Vec<f32>>),
}

impl Actions {
    pub fn n_agents(&self) -> usize {
        match self {
            Actions::Discrete(v) => v.len(),
            Actions::Continuous(v) => v.len(),
        }
    }

    pub fn as_discrete(&self) -> &[i32] {
        match self {
            Actions::Discrete(v) => v,
            _ => panic!("expected discrete actions"),
        }
    }

    pub fn as_continuous(&self) -> &[Vec<f32>] {
        match self {
            Actions::Continuous(v) => v,
            _ => panic!("expected continuous actions"),
        }
    }

    /// Flatten continuous actions to a single [N*A] buffer.
    pub fn flat_continuous(&self) -> Vec<f32> {
        self.as_continuous().iter().flatten().copied().collect()
    }
}

/// A borrowed view of one environment's joint action — the hot-path
/// counterpart of [`Actions`].
///
/// The vectorized executor writes joint actions into a flat
/// struct-of-arrays buffer ([`crate::env::ActionBuf`]); an `ActionsRef`
/// lends one row of that buffer to an environment without materialising
/// the per-agent `Vec`s an owned [`Actions`] carries. The
/// `ContinuousRows` variant adapts the legacy per-agent layout so the
/// same environment stepping code serves both paths.
#[derive(Clone, Copy, Debug)]
pub enum ActionsRef<'a> {
    /// Discrete joint action `[N]`.
    Discrete(&'a [i32]),
    /// Continuous joint action, flat `[N*dim]` row-major by agent.
    Continuous {
        /// Flat action data, agent `i` at `data[i*dim..(i+1)*dim]`.
        data: &'a [f32],
        /// Per-agent action dimension.
        dim: usize,
    },
    /// Continuous joint action in the legacy per-agent-`Vec` layout.
    ContinuousRows(&'a [Vec<f32>]),
}

impl<'a> ActionsRef<'a> {
    /// Borrow an owned [`Actions`] (legacy-path bridge).
    pub fn from_actions(a: &'a Actions) -> ActionsRef<'a> {
        match a {
            Actions::Discrete(v) => ActionsRef::Discrete(v),
            Actions::Continuous(v) => ActionsRef::ContinuousRows(v),
        }
    }

    /// Number of agents in the joint action.
    pub fn n_agents(&self) -> usize {
        match self {
            ActionsRef::Discrete(v) => v.len(),
            ActionsRef::Continuous { data, dim } => {
                if *dim == 0 {
                    0
                } else {
                    data.len() / dim
                }
            }
            ActionsRef::ContinuousRows(v) => v.len(),
        }
    }

    /// Discrete joint action slice; panics on continuous actions.
    pub fn as_discrete(&self) -> &'a [i32] {
        match *self {
            ActionsRef::Discrete(v) => v,
            _ => panic!("expected discrete actions"),
        }
    }

    /// Agent `i`'s continuous action; panics on discrete actions.
    pub fn cont(&self, i: usize) -> &'a [f32] {
        match *self {
            ActionsRef::Continuous { data, dim } => {
                &data[i * dim..(i + 1) * dim]
            }
            ActionsRef::ContinuousRows(v) => &v[i],
            ActionsRef::Discrete(_) => panic!("expected continuous actions"),
        }
    }

    /// Materialise an owned [`Actions`] (allocates — bridge for
    /// environments that only implement the legacy `step`).
    pub fn to_actions(&self) -> Actions {
        match self {
            ActionsRef::Discrete(v) => Actions::Discrete(v.to_vec()),
            ActionsRef::Continuous { data, dim } => Actions::Continuous(
                data.chunks_exact((*dim).max(1)).map(|c| c.to_vec()).collect(),
            ),
            ActionsRef::ContinuousRows(v) => Actions::Continuous(v.to_vec()),
        }
    }
}

/// Action space of one agent.
#[derive(Clone, Debug, PartialEq)]
pub enum ActionSpec {
    Discrete { n: usize },
    Continuous { dim: usize },
}

/// Multi-agent environment spec (Mava's multi-agent `specs`).
#[derive(Clone, Debug)]
pub struct EnvSpec {
    pub name: String,
    pub n_agents: usize,
    /// Per-agent observation dim (already padded for hetero agents).
    pub obs_dim: usize,
    pub action: ActionSpec,
    /// Global state dim (0 when unused).
    pub state_dim: usize,
    /// Hard episode length cap (environments truncate themselves).
    pub episode_limit: usize,
}

impl EnvSpec {
    pub fn discrete(&self) -> bool {
        matches!(self.action, ActionSpec::Discrete { .. })
    }

    pub fn n_actions(&self) -> usize {
        match self.action {
            ActionSpec::Discrete { n } => n,
            ActionSpec::Continuous { dim } => dim,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timestep_helpers() {
        let ts = TimeStep {
            step_type: StepType::Last,
            observations: vec![vec![0.0; 3]; 2],
            rewards: vec![1.0, 2.0],
            discount: 0.0,
            state: vec![],
            legal_actions: None,
        };
        assert!(ts.is_last());
        assert_eq!(ts.n_agents(), 2);
        assert_eq!(ts.team_reward(), 3.0);
    }

    #[test]
    fn actions_accessors() {
        let a = Actions::Discrete(vec![0, 2, 1]);
        assert_eq!(a.n_agents(), 3);
        assert_eq!(a.as_discrete(), &[0, 2, 1]);
        let c = Actions::Continuous(vec![vec![0.1, 0.2], vec![0.3, 0.4]]);
        assert_eq!(c.flat_continuous(), vec![0.1, 0.2, 0.3, 0.4]);
    }

    #[test]
    fn actions_ref_views() {
        let d = Actions::Discrete(vec![1, 2]);
        let r = ActionsRef::from_actions(&d);
        assert_eq!(r.n_agents(), 2);
        assert_eq!(r.as_discrete(), &[1, 2]);
        assert_eq!(r.to_actions().as_discrete(), &[1, 2]);

        let flat = [0.1f32, 0.2, 0.3, 0.4];
        let f = ActionsRef::Continuous { data: &flat, dim: 2 };
        assert_eq!(f.n_agents(), 2);
        assert_eq!(f.cont(1), &[0.3, 0.4]);
        assert_eq!(f.to_actions().flat_continuous(), flat.to_vec());

        let rows = vec![vec![1.0f32], vec![2.0]];
        let c = Actions::Continuous(rows);
        let rr = ActionsRef::from_actions(&c);
        assert_eq!(rr.cont(0), &[1.0]);
        assert_eq!(rr.n_agents(), 2);
    }

    #[test]
    fn spec_helpers() {
        let s = EnvSpec {
            name: "t".into(),
            n_agents: 2,
            obs_dim: 4,
            action: ActionSpec::Discrete { n: 3 },
            state_dim: 8,
            episode_limit: 10,
        };
        assert!(s.discrete());
        assert_eq!(s.n_actions(), 3);
    }
}
