//! Host-side tensors: the typed buffers exchanged with the PJRT runtime.

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// A dense host tensor (row-major), either f32 or i32.
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    data_f32: Vec<f32>,
    data_i32: Vec<i32>,
    pub dtype: Dtype,
}

impl HostTensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data_f32: data, data_i32: vec![], dtype: Dtype::F32 }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims, data_f32: vec![], data_i32: data, dtype: Dtype::I32 }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::f32(vec![], vec![x])
    }

    pub fn zeros_f32(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        HostTensor::f32(dims, vec![0.0; n])
    }

    pub fn zeros_i32(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        HostTensor::i32(dims, vec![0; n])
    }

    pub fn len(&self) -> usize {
        match self.dtype {
            Dtype::F32 => self.data_f32.len(),
            Dtype::I32 => self.data_i32.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        debug_assert_eq!(self.dtype, Dtype::F32);
        &self.data_f32
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        debug_assert_eq!(self.dtype, Dtype::F32);
        &mut self.data_f32
    }

    pub fn as_i32(&self) -> &[i32] {
        debug_assert_eq!(self.dtype, Dtype::I32);
        &self.data_i32
    }

    pub fn as_i32_mut(&mut self) -> &mut [i32] {
        debug_assert_eq!(self.dtype, Dtype::I32);
        &mut self.data_i32
    }

    pub fn into_f32(self) -> Vec<f32> {
        debug_assert_eq!(self.dtype, Dtype::F32);
        self.data_f32
    }

    /// Borrow chunk `i` of `len` contiguous f32 elements — row `i` of a
    /// tensor whose leading axis strides by `len` (the SoA batch-buffer
    /// row accessor).
    pub fn f32_chunk(&self, i: usize, len: usize) -> &[f32] {
        &self.as_f32()[i * len..(i + 1) * len]
    }

    /// Mutable [`HostTensor::f32_chunk`].
    pub fn f32_chunk_mut(&mut self, i: usize, len: usize) -> &mut [f32] {
        &mut self.as_f32_mut()[i * len..(i + 1) * len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32()[4], 5.0);
        let i = HostTensor::i32(vec![2], vec![7, 8]);
        assert_eq!(i.as_i32(), &[7, 8]);
    }

    #[test]
    fn scalar_has_empty_dims() {
        let s = HostTensor::scalar_f32(3.5);
        assert!(s.dims.is_empty());
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn chunk_views_rows() {
        let mut t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.f32_chunk(1, 3), &[4., 5., 6.]);
        t.f32_chunk_mut(0, 3).fill(0.0);
        assert_eq!(t.as_f32(), &[0., 0., 0., 4., 5., 6.]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }
}
