//! Launchpad-style program graphs (paper Block 2).
//!
//! A [`Program`] is a named multi-node graph; each node is a fallible
//! closure run on its own OS thread by the [`LocalLauncher`] (the
//! analogue of `launchpad.launch(program, LaunchType.LOCAL_MULTI_PROCESSING)`
//! — we use threads instead of processes; the executor-parallelism the
//! paper's Fig 6 bottom-right measures is preserved, see DESIGN.md §2).
//! Nodes coordinate shutdown through a shared [`StopSignal`].
//!
//! Node failures are a *typed channel*, not stderr noise: a node body
//! returns `Result<()>` (panics are caught and converted), a failing
//! node immediately trips the program's [`StopSignal`] so its siblings
//! wind down instead of training against a dead peer, and
//! [`LaunchHandle::join`] returns one [`NodeOutcome`] per node so the
//! supervisor can name exactly which node failed and why.

#![warn(missing_docs)]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

pub mod dist;
pub mod supervise;

/// Cooperative shutdown flag shared by every node of a program.
#[derive(Clone, Default)]
pub struct StopSignal {
    flag: Arc<AtomicBool>,
}

impl StopSignal {
    /// Fresh signal in the running (not stopped) state.
    pub fn new() -> Self {
        StopSignal::default()
    }

    /// Request shutdown; every clone observes it.
    pub fn stop(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// Whether shutdown has been requested.
    pub fn is_stopped(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// Node category — mirrors the paper's program graph (Block 2 inset):
/// replay table node, trainer courier node, executor courier nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Replay table node.
    Replay,
    /// Versioned parameter server node.
    ParameterServer,
    /// Trainer (learner) courier node.
    Trainer,
    /// Executor (actor) courier node.
    Executor,
    /// Evaluator node.
    Evaluator,
}

struct NodeSpec {
    name: String,
    kind: NodeKind,
    body: Box<dyn FnOnce() -> Result<()> + Send + 'static>,
}

/// A multi-node program under construction (Launchpad's program graph).
#[derive(Default)]
pub struct Program {
    nodes: Vec<NodeSpec>,
}

impl Program {
    /// An empty program graph.
    pub fn new() -> Self {
        Program::default()
    }

    /// Add a node; `body` runs on its own thread at launch. An `Err`
    /// (or a panic) from `body` trips the program's [`StopSignal`] and
    /// is reported in the node's [`NodeOutcome`] at join.
    pub fn add_node(
        &mut self,
        name: impl Into<String>,
        kind: NodeKind,
        body: impl FnOnce() -> Result<()> + Send + 'static,
    ) -> &mut Self {
        self.nodes.push(NodeSpec { name: name.into(), kind, body: Box::new(body) });
        self
    }

    /// Names of every node, in insertion order.
    pub fn node_names(&self) -> Vec<&str> {
        self.nodes.iter().map(|n| n.name.as_str()).collect()
    }

    /// Number of nodes of the given kind.
    pub fn count(&self, kind: NodeKind) -> usize {
        self.nodes.iter().filter(|n| n.kind == kind).count()
    }
}

/// What one node of a launched program did: ran to completion
/// (`result` Ok) or failed with the propagated error (a body `Err` or
/// a caught panic).
pub struct NodeOutcome {
    /// Node name, as given to [`Program::add_node`].
    pub name: String,
    /// Node category.
    pub kind: NodeKind,
    /// The node body's result; panics are converted to errors.
    pub result: Result<()>,
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A launched program: join to collect per-node outcomes.
pub struct LaunchHandle {
    threads: Vec<(String, NodeKind, JoinHandle<Result<()>>)>,
    /// The program's shared shutdown signal.
    pub stop: StopSignal,
}

impl LaunchHandle {
    /// Wait for every node to finish and return one [`NodeOutcome`]
    /// per node, in launch order.
    pub fn join(self) -> Vec<NodeOutcome> {
        self.threads
            .into_iter()
            .map(|(name, kind, h)| {
                let result = match h.join() {
                    Ok(r) => r,
                    // the body wrapper catches panics, so this only
                    // fires if the thread died outside it
                    Err(p) => {
                        Err(anyhow!("node panicked: {}", panic_message(&*p)))
                    }
                };
                NodeOutcome { name, kind, result }
            })
            .collect()
    }

    /// Join and collapse the outcomes into one result: `Ok` if every
    /// node succeeded, otherwise an error naming the failed node(s)
    /// with the first failure's message.
    pub fn join_all(self) -> Result<()> {
        outcomes_to_result(&self.join())
    }

    /// [`LaunchHandle::join`] with a deadline: waits up to `timeout`
    /// for every node to finish, joining them as they complete. A node
    /// still running at the deadline — e.g. wedged in a blocking socket
    /// read that no [`StopSignal`] can interrupt — is *abandoned* (its
    /// `JoinHandle` is dropped, the thread detaches) and its
    /// [`NodeOutcome`] is an `Err` naming it as stuck, instead of
    /// hanging the supervisor forever.
    pub fn join_deadline(self, timeout: Duration) -> Vec<NodeOutcome> {
        let deadline = Instant::now() + timeout;
        let mut slots: Vec<
            Option<(String, NodeKind, JoinHandle<Result<()>>)>,
        > = self.threads.into_iter().map(Some).collect();
        let mut outcomes: Vec<Option<NodeOutcome>> =
            (0..slots.len()).map(|_| None).collect();
        loop {
            let mut pending = false;
            for (i, slot) in slots.iter_mut().enumerate() {
                let finished = match slot {
                    Some((_, _, h)) => h.is_finished(),
                    None => continue,
                };
                if !finished {
                    pending = true;
                    continue;
                }
                let (name, kind, h) = slot.take().unwrap();
                let result = match h.join() {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!(
                        "node panicked: {}",
                        panic_message(&*p)
                    )),
                };
                outcomes[i] = Some(NodeOutcome { name, kind, result });
            }
            if !pending || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(crate::net::frame::POLL_INTERVAL);
        }
        for (i, slot) in slots.into_iter().enumerate() {
            if let Some((name, kind, _abandoned)) = slot {
                outcomes[i] = Some(NodeOutcome {
                    name,
                    kind,
                    result: Err(anyhow!(
                        "node stuck: did not exit within {timeout:?} \
                         after shutdown was requested (thread abandoned)"
                    )),
                });
            }
        }
        outcomes
            .into_iter()
            .map(|o| o.expect("every slot resolved"))
            .collect()
    }

    /// [`LaunchHandle::join_all`] with the deadline semantics of
    /// [`LaunchHandle::join_deadline`].
    pub fn join_all_deadline(self, timeout: Duration) -> Result<()> {
        outcomes_to_result(&self.join_deadline(timeout))
    }

    /// Signal shutdown and wait.
    pub fn stop_and_join(self) -> Vec<NodeOutcome> {
        self.stop.stop();
        self.join()
    }
}

/// The canonical error for failed program nodes, built from
/// `(node name, rendered error)` pairs: names the node — or lists all
/// of them — and carries the first failure's message. Every layer
/// that reports node failures ([`outcomes_to_result`], the system
/// supervisor) formats through this one function.
///
/// `failed` must be non-empty.
pub fn node_failure_error(failed: &[(&str, &str)]) -> anyhow::Error {
    let (node, err) = failed[0];
    if failed.len() == 1 {
        return anyhow!("node {node} failed: {err}");
    }
    let names: Vec<&str> = failed.iter().map(|(n, _)| *n).collect();
    anyhow!(
        "{} nodes failed ({}); first: node {node} failed: {err}",
        failed.len(),
        names.join(", ")
    )
}

/// Collapse per-node outcomes into one result: `Ok` when every node
/// succeeded, otherwise [`node_failure_error`] over the failures.
pub fn outcomes_to_result(outcomes: &[NodeOutcome]) -> Result<()> {
    let rendered: Vec<(String, String)> = outcomes
        .iter()
        .filter_map(|o| {
            o.result
                .as_ref()
                .err()
                .map(|e| (o.name.clone(), format!("{e:#}")))
        })
        .collect();
    if rendered.is_empty() {
        return Ok(());
    }
    let pairs: Vec<(&str, &str)> =
        rendered.iter().map(|(n, e)| (n.as_str(), e.as_str())).collect();
    Err(node_failure_error(&pairs))
}

/// Local multi-threaded launcher.
pub struct LocalLauncher;

impl LocalLauncher {
    /// Launch every node of `program` on its own thread. A node that
    /// returns `Err` or panics trips `stop`, so sibling nodes shut
    /// down instead of running against a dead peer; the failure is
    /// reported through [`LaunchHandle::join`].
    pub fn launch(program: Program, stop: StopSignal) -> LaunchHandle {
        let threads = program
            .nodes
            .into_iter()
            .map(|spec| {
                let name = spec.name.clone();
                let kind = spec.kind;
                let body = spec.body;
                let node_stop = stop.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("mava-{}", spec.name))
                    .spawn(move || -> Result<()> {
                        let result = match catch_unwind(AssertUnwindSafe(body))
                        {
                            Ok(r) => r,
                            Err(p) => Err(anyhow!(
                                "node panicked: {}",
                                panic_message(&*p)
                            )),
                        };
                        if result.is_err() {
                            node_stop.stop();
                        }
                        result
                    })
                    .expect("spawn node thread");
                (name, kind, handle)
            })
            .collect();
        LaunchHandle { threads, stop }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn nodes_all_run_and_join() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut p = Program::new();
        for i in 0..4 {
            let c = counter.clone();
            p.add_node(format!("exec_{i}"), NodeKind::Executor, move || {
                c.fetch_add(1, Ordering::SeqCst);
                Ok(())
            });
        }
        assert_eq!(p.count(NodeKind::Executor), 4);
        let h = LocalLauncher::launch(p, StopSignal::new());
        let outcomes = h.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
        assert_eq!(outcomes.len(), 4);
        assert!(outcomes.iter().all(|o| o.result.is_ok()));
        assert!(outcomes_to_result(&outcomes).is_ok());
    }

    #[test]
    fn stop_signal_reaches_nodes() {
        let stop = StopSignal::new();
        let mut p = Program::new();
        let s = stop.clone();
        let spins = Arc::new(AtomicUsize::new(0));
        let spins2 = spins.clone();
        p.add_node("worker", NodeKind::Trainer, move || {
            while !s.is_stopped() {
                spins2.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            Ok(())
        });
        let h = LocalLauncher::launch(p, stop.clone());
        // poll for the observable condition (the worker has spun)
        // rather than sleeping a guessed duration (R6, DESIGN.md §14)
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_secs(10);
        while spins.load(Ordering::Relaxed) == 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "worker never started spinning"
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let outcomes = h.stop_and_join();
        assert!(spins.load(Ordering::Relaxed) > 0);
        assert!(stop.is_stopped());
        assert!(outcomes[0].result.is_ok());
    }

    #[test]
    fn graph_introspection() {
        let mut p = Program::new();
        p.add_node("replay", NodeKind::Replay, || Ok(()));
        p.add_node("trainer", NodeKind::Trainer, || Ok(()));
        assert_eq!(p.node_names(), vec!["replay", "trainer"]);
    }

    /// Satellite: node errors are a typed channel. An erroring node's
    /// failure (a) trips the StopSignal so siblings wind down and
    /// (b) surfaces through join with the node's name — no stderr
    /// scraping.
    #[test]
    fn node_error_trips_stop_and_names_the_node() {
        let stop = StopSignal::new();
        let mut p = Program::new();
        let s = stop.clone();
        p.add_node("worker", NodeKind::Executor, move || {
            // a well-behaved sibling: spins until stopped
            while !s.is_stopped() {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            Ok(())
        });
        p.add_node("trainer", NodeKind::Trainer, || {
            anyhow::bail!("replay table corrupt")
        });
        let h = LocalLauncher::launch(p, stop.clone());
        let outcomes = h.join(); // terminates: the error stops the sibling
        assert!(stop.is_stopped(), "error must trip the stop signal");
        assert!(outcomes[0].result.is_ok());
        let err = outcomes[1].result.as_ref().unwrap_err();
        assert!(err.to_string().contains("replay table corrupt"));
        let collapsed = outcomes_to_result(&outcomes).unwrap_err();
        assert!(
            collapsed.to_string().contains("node trainer failed"),
            "must name the failed node: {collapsed}"
        );
        assert!(collapsed.to_string().contains("replay table corrupt"));
    }

    /// Satellite: a node wedged in a blocking call cannot hang the
    /// supervisor — `join_deadline` abandons it and reports it *by
    /// name* while well-behaved siblings join normally.
    #[test]
    fn join_deadline_names_the_stuck_node() {
        let stop = StopSignal::new();
        let mut p = Program::new();
        let s = stop.clone();
        let spins = Arc::new(AtomicUsize::new(0));
        let spins2 = spins.clone();
        p.add_node("executor_0", NodeKind::Executor, move || {
            while !s.is_stopped() {
                spins2.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            Ok(())
        });
        p.add_node("trainer", NodeKind::Trainer, || {
            // simulates a blocking socket read with no timeout: never
            // observes the stop signal
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        });
        let h = LocalLauncher::launch(p, stop.clone());
        // both nodes are up once the sibling is observably spinning;
        // poll for that instead of sleeping a guessed duration
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_secs(10);
        while spins.load(Ordering::Relaxed) == 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "sibling never started spinning"
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        stop.stop();
        let outcomes =
            h.join_deadline(std::time::Duration::from_millis(200));
        assert_eq!(outcomes.len(), 2);
        assert!(outcomes[0].result.is_ok(), "sibling joined cleanly");
        let err = outcomes[1].result.as_ref().unwrap_err();
        assert!(
            err.to_string().contains("stuck"),
            "stuck node reported: {err}"
        );
        let collapsed = outcomes_to_result(&outcomes).unwrap_err();
        assert!(
            collapsed.to_string().contains("node trainer failed"),
            "must name the stuck node: {collapsed}"
        );
    }

    /// Panics flow through the same channel as errors.
    #[test]
    fn node_panic_is_caught_and_propagated() {
        let stop = StopSignal::new();
        let mut p = Program::new();
        p.add_node("evaluator", NodeKind::Evaluator, || {
            panic!("index out of bounds (simulated)")
        });
        let h = LocalLauncher::launch(p, stop.clone());
        let outcomes = h.join();
        assert!(stop.is_stopped(), "panic must trip the stop signal");
        let err = outcomes[0].result.as_ref().unwrap_err();
        assert!(
            err.to_string().contains("index out of bounds"),
            "panic message preserved: {err}"
        );
        let collapsed = outcomes_to_result(&outcomes).unwrap_err();
        assert!(collapsed.to_string().contains("node evaluator failed"));
    }
}
