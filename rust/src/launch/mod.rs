//! Launchpad-style program graphs (paper Block 2).
//!
//! A [`Program`] is a named multi-node graph; each node is a closure run
//! on its own OS thread by the [`LocalLauncher`] (the analogue of
//! `launchpad.launch(program, LaunchType.LOCAL_MULTI_PROCESSING)` — we use
//! threads instead of processes; the executor-parallelism the paper's
//! Fig 6 bottom-right measures is preserved, see DESIGN.md §2). Nodes
//! coordinate shutdown through a shared [`StopSignal`].

#![warn(missing_docs)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Cooperative shutdown flag shared by every node of a program.
#[derive(Clone, Default)]
pub struct StopSignal {
    flag: Arc<AtomicBool>,
}

impl StopSignal {
    /// Fresh signal in the running (not stopped) state.
    pub fn new() -> Self {
        StopSignal::default()
    }

    /// Request shutdown; every clone observes it.
    pub fn stop(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// Whether shutdown has been requested.
    pub fn is_stopped(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// Node category — mirrors the paper's program graph (Block 2 inset):
/// replay table node, trainer courier node, executor courier nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Replay table node.
    Replay,
    /// Versioned parameter server node.
    ParameterServer,
    /// Trainer (learner) courier node.
    Trainer,
    /// Executor (actor) courier node.
    Executor,
    /// Evaluator node.
    Evaluator,
}

struct NodeSpec {
    name: String,
    kind: NodeKind,
    body: Box<dyn FnOnce() + Send + 'static>,
}

/// A multi-node program under construction (Launchpad's program graph).
#[derive(Default)]
pub struct Program {
    nodes: Vec<NodeSpec>,
}

impl Program {
    /// An empty program graph.
    pub fn new() -> Self {
        Program::default()
    }

    /// Add a node; `body` runs on its own thread at launch.
    pub fn add_node(
        &mut self,
        name: impl Into<String>,
        kind: NodeKind,
        body: impl FnOnce() + Send + 'static,
    ) -> &mut Self {
        self.nodes.push(NodeSpec { name: name.into(), kind, body: Box::new(body) });
        self
    }

    /// Names of every node, in insertion order.
    pub fn node_names(&self) -> Vec<&str> {
        self.nodes.iter().map(|n| n.name.as_str()).collect()
    }

    /// Number of nodes of the given kind.
    pub fn count(&self, kind: NodeKind) -> usize {
        self.nodes.iter().filter(|n| n.kind == kind).count()
    }
}

/// A launched program: join to wait for completion.
pub struct LaunchHandle {
    threads: Vec<(String, JoinHandle<()>)>,
    /// The program's shared shutdown signal.
    pub stop: StopSignal,
}

impl LaunchHandle {
    /// Wait for every node to finish.
    pub fn join(self) {
        for (name, h) in self.threads {
            if h.join().is_err() {
                eprintln!("[launch] node {name} panicked");
            }
        }
    }

    /// Signal shutdown and wait.
    pub fn stop_and_join(self) {
        self.stop.stop();
        self.join();
    }
}

/// Local multi-threaded launcher.
pub struct LocalLauncher;

impl LocalLauncher {
    /// Launch every node of `program` on its own thread.
    pub fn launch(program: Program, stop: StopSignal) -> LaunchHandle {
        let threads = program
            .nodes
            .into_iter()
            .map(|spec| {
                let name = spec.name.clone();
                let body = spec.body;
                let handle = std::thread::Builder::new()
                    .name(format!("mava-{}", spec.name))
                    .spawn(body)
                    .expect("spawn node thread");
                (name, handle)
            })
            .collect();
        LaunchHandle { threads, stop }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn nodes_all_run_and_join() {
        let counter = Arc::new(AtomicUsize::new(0));
        let mut p = Program::new();
        for i in 0..4 {
            let c = counter.clone();
            p.add_node(format!("exec_{i}"), NodeKind::Executor, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(p.count(NodeKind::Executor), 4);
        let h = LocalLauncher::launch(p, StopSignal::new());
        h.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn stop_signal_reaches_nodes() {
        let stop = StopSignal::new();
        let mut p = Program::new();
        let s = stop.clone();
        let spins = Arc::new(AtomicUsize::new(0));
        let spins2 = spins.clone();
        p.add_node("worker", NodeKind::Trainer, move || {
            while !s.is_stopped() {
                spins2.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        });
        let h = LocalLauncher::launch(p, stop.clone());
        std::thread::sleep(std::time::Duration::from_millis(20));
        h.stop_and_join();
        assert!(spins.load(Ordering::Relaxed) > 0);
        assert!(stop.is_stopped());
    }

    #[test]
    fn graph_introspection() {
        let mut p = Program::new();
        p.add_node("replay", NodeKind::Replay, || {});
        p.add_node("trainer", NodeKind::Trainer, || {});
        assert_eq!(p.node_names(), vec!["replay", "trainer"]);
    }
}
