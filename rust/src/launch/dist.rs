//! Multi-process distributed launch (DESIGN.md §10): the `mava node`
//! and `mava launch` entry points.
//!
//! [`run_node`] runs ONE node of the program graph — a parameter
//! server, a replay shard, the trainer, an executor or the evaluator —
//! in the current process, wired to its peers over the
//! [`crate::net`] protocols. [`launch`] is the driver: it binds a
//! [`ControlServer`], spawns one `mava node` child process per graph
//! node (re-executing the current binary), discovers service
//! addresses through the nodes' `Hello` registrations, supervises the
//! children, and maps every child exit into a typed
//! [`NodeOutcome`] — so a dead remote node trips the stop signal and
//! is reported *by name*, exactly like a dead thread under the
//! in-process [`crate::launch::LocalLauncher`].
//!
//! The node loops themselves are the unchanged structs from
//! [`crate::systems::nodes`]: only the handles differ (remote clients
//! instead of in-process `Arc`s). In-process threads stay the default
//! launcher; this module is opt-in via `mava launch`.

use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::env::wrappers::Fingerprint;
use crate::launch::supervise::{
    supervise, SupervisedSpec, Supervision, SupervisorConfig,
};
use crate::launch::{outcomes_to_result, NodeKind, StopSignal};
use crate::metrics::{Counters, MovingStats};
use crate::net::control::{ControlClient, ControlServer};
use crate::net::param::{ParamService, RemoteParamClient};
use crate::net::replay::{
    RemoteReplaySampler, RemoteShardClient, ReplayService,
};
use crate::net::retry::RetryPolicy;
use crate::params::{ParamStore, ParameterServer};
use crate::replay::{ItemSink, RateLimiter, Selector, Table};
use crate::runtime::{Engine, Manifest};
use crate::systems::nodes::{
    EnvFactory, ExecutorNode, SystemHandles, TrainerNode,
};
use crate::systems::{env_for_preset, make_vec_evaluator_with, SystemSpec};

/// Which node of the program graph a `mava node` process runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// The versioned parameter server service.
    Param,
    /// Replay shard `k` (one [`Table`] behind a [`ReplayService`]).
    Replay(usize),
    /// The trainer.
    Trainer,
    /// Executor `k` (inserts into replay shard `k`).
    Executor(usize),
    /// The evaluator.
    Evaluator,
}

impl Role {
    /// Parse a `--role` argument: `param`, `replay:K`, `trainer`,
    /// `executor:K` or `evaluator`.
    pub fn parse(s: &str) -> Result<Role> {
        if let Some(k) = s.strip_prefix("replay:") {
            return Ok(Role::Replay(
                k.parse().with_context(|| format!("bad role {s:?}"))?,
            ));
        }
        if let Some(k) = s.strip_prefix("executor:") {
            return Ok(Role::Executor(
                k.parse().with_context(|| format!("bad role {s:?}"))?,
            ));
        }
        match s {
            "param" => Ok(Role::Param),
            "trainer" => Ok(Role::Trainer),
            "evaluator" => Ok(Role::Evaluator),
            other => bail!(
                "unknown role {other:?} (expected param | replay:K | \
                 trainer | executor:K | evaluator)"
            ),
        }
    }

    /// The `--role` argument spelling (inverse of [`Role::parse`]).
    pub fn arg(&self) -> String {
        match self {
            Role::Param => "param".into(),
            Role::Replay(k) => format!("replay:{k}"),
            Role::Trainer => "trainer".into(),
            Role::Executor(k) => format!("executor:{k}"),
            Role::Evaluator => "evaluator".into(),
        }
    }

    /// Node name in the program graph (what failures are reported as).
    pub fn name(&self) -> String {
        match self {
            Role::Param => "param_server".into(),
            Role::Replay(k) => format!("replay_{k}"),
            Role::Trainer => "trainer".into(),
            Role::Executor(k) => format!("executor_{k}"),
            Role::Evaluator => "evaluator".into(),
        }
    }

    /// Node category for the typed outcome channel.
    pub fn kind(&self) -> NodeKind {
        match self {
            Role::Param => NodeKind::ParameterServer,
            Role::Replay(_) => NodeKind::Replay,
            Role::Trainer => NodeKind::Trainer,
            Role::Executor(_) => NodeKind::Executor,
            Role::Evaluator => NodeKind::Evaluator,
        }
    }
}

/// Wiring arguments of one `mava node` process (everything beyond the
/// shared [`TrainConfig`]).
#[derive(Clone, Debug)]
pub struct NodeOpts {
    /// The node to run.
    pub role: Role,
    /// Address of the driver's [`ControlServer`].
    pub control: String,
    /// Parameter-service address (trainer / executor / evaluator).
    pub param: Option<String>,
    /// Replay-service addresses, shard order (trainer gets all,
    /// executor `k` uses entry `k`).
    pub replay: Vec<String>,
}

/// Artifact metadata shared by the replay / trainer / executor roles,
/// resolved exactly like the in-process builder resolves it.
struct TrainMeta {
    spec: &'static SystemSpec,
    train_name: String,
    exec_policy_name: String,
    params0: Vec<f32>,
    opt0: Vec<f32>,
    batch: usize,
    gamma: f32,
    seq_len: usize,
}

fn train_meta(cfg: &TrainConfig) -> Result<TrainMeta> {
    let spec = SystemSpec::parse(&cfg.system)?;
    let prefix = spec.artifact_prefix(&cfg.preset, cfg.arch);
    let train_name = spec.train_artifact(&prefix);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    // executors act at the lowered bucket num_envs rounds UP to
    // (DESIGN.md §11), exactly like the in-process builder
    let ladder = crate::runtime::BucketLadder::from_manifest(
        &manifest,
        &spec.policy_artifact(&prefix),
    )?;
    let (bucket, _pad) =
        ladder.pick(cfg.num_envs_per_executor.max(1))?;
    let exec_policy_name = ladder.artifact_name(bucket);
    let train_art = manifest.get(&train_name)?.clone();
    Ok(TrainMeta {
        spec,
        train_name,
        exec_policy_name,
        params0: manifest.read_init(&train_art, "params0")?,
        opt0: manifest.read_init(&train_art, "opt0")?,
        batch: train_art.meta_usize("batch")?,
        gamma: train_art.meta_f32("gamma")?,
        seq_len: train_art.meta_usize("seq_len")?,
    })
}

/// Per-process [`SystemHandles`] over a remote parameter store. The
/// counters are process-local: in a multi-process run each executor
/// paces itself against `max_env_steps` (there is no global step
/// counter on the wire), so budgets are per-executor.
fn remote_handles(
    server: Arc<dyn ParamStore>,
    stop: StopSignal,
    cfg: &TrainConfig,
) -> SystemHandles {
    SystemHandles {
        server,
        counters: Arc::new(Counters::default()),
        stop,
        evals: Arc::new(Mutex::new(Vec::new())),
        train_returns: Arc::new(Mutex::new(MovingStats::new(64))),
        fingerprint: Fingerprint::new(cfg.eps_start, 0.0),
        started: Instant::now(),
    }
}

fn rpc_timeout(cfg: &TrainConfig) -> Duration {
    Duration::from_secs(cfg.dist_timeout_s.max(1))
}

/// Run one node of the program graph in the current process until the
/// driver broadcasts `Stop` (or the node's own budget completes).
/// This is `mava node`'s body; a returned error makes the process
/// exit non-zero, which the driver maps into the node's
/// [`NodeOutcome`].
pub fn run_node(cfg: &TrainConfig, opts: &NodeOpts) -> Result<()> {
    let stop = StopSignal::new();
    let name = opts.role.name();
    let role_arg = opts.role.arg();
    match opts.role {
        Role::Param => {
            let server = Arc::new(ParameterServer::new(Vec::new()));
            let mut svc = ParamService::bind(server, &cfg.bind_host)?;
            let ctl = ControlClient::connect(
                &opts.control,
                &name,
                &role_arg,
                svc.addr(),
            )?;
            let _watch = ctl.watch_stop(stop.clone())?;
            let _beat = ctl.start_heartbeat(
                Duration::from_millis(cfg.heartbeat_interval_ms),
                stop.clone(),
            )?;
            while !stop.is_stopped() {
                std::thread::sleep(crate::net::frame::POLL_INTERVAL);
            }
            svc.shutdown();
            Ok(())
        }
        Role::Replay(k) => {
            // the same sharding arithmetic as the in-process
            // ShardedTable: capacity and rate limiter are the global
            // figures scaled down to one shard of `num_executors`
            let meta = train_meta(cfg)?;
            let shards = cfg.num_executors.max(1);
            let limiter = RateLimiter::sample_to_insert(
                cfg.samples_per_insert / meta.batch as f64,
                cfg.min_replay,
            )
            .per_shard(shards);
            let table = Arc::new(Table::new(
                (cfg.replay_size / shards).max(1),
                Selector::Uniform,
                limiter,
                (cfg.seed ^ 0x7ab1e)
                    .wrapping_add(0x9e37_79b9u64.wrapping_mul(k as u64 + 1)),
            ));
            let mut svc =
                ReplayService::bind(table.clone(), &cfg.bind_host)?;
            let ctl = ControlClient::connect(
                &opts.control,
                &name,
                &role_arg,
                svc.addr(),
            )?;
            let _watch = ctl.watch_stop(stop.clone())?;
            let _beat = ctl.start_heartbeat(
                Duration::from_millis(cfg.heartbeat_interval_ms),
                stop.clone(),
            )?;
            while !stop.is_stopped() {
                std::thread::sleep(crate::net::frame::POLL_INTERVAL);
            }
            // close BEFORE service shutdown: unblocks rate-limited
            // inserts and makes in-flight samplers see SourceClosed
            table.close();
            svc.shutdown();
            Ok(())
        }
        Role::Trainer => {
            let meta = train_meta(cfg)?;
            let param_addr = opts
                .param
                .as_deref()
                .context("trainer role needs --param ADDR")?;
            anyhow::ensure!(
                !opts.replay.is_empty(),
                "trainer role needs --replay ADDR (one per shard)"
            );
            let server = Arc::new(RemoteParamClient::connect(
                param_addr,
                rpc_timeout(cfg),
            )?);
            let source = Arc::new(RemoteReplaySampler::connect(
                &opts.replay,
                rpc_timeout(cfg),
            )?);
            let ctl =
                ControlClient::connect(&opts.control, &name, &role_arg, "")?;
            let _watch = ctl.watch_stop(stop.clone())?;
            let _beat = ctl.start_heartbeat(
                Duration::from_millis(cfg.heartbeat_interval_ms),
                stop.clone(),
            )?;
            let mut node = TrainerNode {
                spec: meta.spec,
                cfg: cfg.clone(),
                handles: remote_handles(server, stop, cfg),
                train_name: meta.train_name,
                params0: meta.params0,
                opt0: meta.opt0,
                source,
                checkpoint: crate::systems::trainer_checkpoint_path(cfg),
            };
            node.run()
        }
        Role::Executor(k) => {
            let meta = train_meta(cfg)?;
            let param_addr = opts
                .param
                .as_deref()
                .context("executor role needs --param ADDR")?;
            let shard_addr = opts.replay.get(k).with_context(|| {
                format!("executor:{k} needs --replay ADDR #{k}")
            })?;
            let server = Arc::new(RemoteParamClient::connect(
                param_addr,
                rpc_timeout(cfg),
            )?);
            let shard: Arc<dyn ItemSink> =
                Arc::new(RemoteShardClient::connect(shard_addr)?);
            let ctl =
                ControlClient::connect(&opts.control, &name, &role_arg, "")?;
            let _watch = ctl.watch_stop(stop.clone())?;
            let _beat = ctl.start_heartbeat(
                Duration::from_millis(cfg.heartbeat_interval_ms),
                stop.clone(),
            )?;
            let preset = cfg.preset.clone();
            let env_factory: EnvFactory =
                Arc::new(move |s, fp| env_for_preset(&preset, s, fp));
            let spec = meta.spec;
            let (n_step, gamma, seq_len) =
                (cfg.n_step, meta.gamma, meta.seq_len);
            let mut node = ExecutorNode {
                worker: k,
                spec,
                cfg: cfg.clone(),
                handles: remote_handles(server, stop, cfg),
                shard,
                policy_name: meta.exec_policy_name,
                params0: meta.params0,
                env_factory,
                adder_factory: Arc::new(move |shard| {
                    spec.make_adder(shard, n_step, gamma, seq_len)
                }),
            };
            node.run()
        }
        Role::Evaluator => {
            let meta = train_meta(cfg)?;
            let param_addr = opts
                .param
                .as_deref()
                .context("evaluator role needs --param ADDR")?;
            let server = Arc::new(RemoteParamClient::connect(
                param_addr,
                rpc_timeout(cfg),
            )?);
            let ctl =
                ControlClient::connect(&opts.control, &name, &role_arg, "")?;
            let _watch = ctl.watch_stop(stop.clone())?;
            let _beat = ctl.start_heartbeat(
                Duration::from_millis(cfg.heartbeat_interval_ms),
                stop.clone(),
            )?;
            let preset = cfg.preset.clone();
            let env_factory: EnvFactory =
                Arc::new(move |s, fp| env_for_preset(&preset, s, fp));
            run_remote_evaluator(cfg, meta, server, stop, &env_factory)
        }
    }
}

/// The evaluator loop for multi-process runs.
/// [`crate::systems::nodes::EvaluatorNode`] paces itself on the shared
/// env-step counter, which does not exist across
/// processes — here evaluation is paced by *parameter version*
/// instead: a wave runs whenever the parameter server has published
/// something newer than the last wave saw.
fn run_remote_evaluator(
    cfg: &TrainConfig,
    meta: TrainMeta,
    server: Arc<dyn ParamStore>,
    stop: StopSignal,
    env_factory: &EnvFactory,
) -> Result<()> {
    let mut engine = Engine::load(&cfg.artifacts_dir)?;
    let started = Instant::now();
    let mut evaluator = make_vec_evaluator_with(
        &mut engine,
        cfg,
        meta.params0.clone(),
        cfg.eval_episodes,
        cfg.seed ^ 0xe7a1,
        env_factory,
    )?;
    let mut buf = Vec::new();
    while !stop.is_stopped() {
        let synced = server.sync(evaluator.params_version(), &mut buf)?;
        let Some(v) = synced else {
            // nothing new published yet
            std::thread::sleep(crate::net::frame::POLL_INTERVAL);
            continue;
        };
        evaluator.set_params(v, &buf);
        let returns = evaluator
            .evaluate_until(cfg.eval_episodes, || stop.is_stopped())?;
        if returns.is_empty() {
            continue;
        }
        println!(
            "eval t={:<7.1}s params_v={v:<6} return={:.3}",
            started.elapsed().as_secs_f64(),
            crate::eval::stats::mean(&returns)
        );
    }
    Ok(())
}

/// One spawned `mava node` child under supervision.
struct ChildNode {
    role: Role,
    child: Child,
}

fn spawn_role(
    cfg: &TrainConfig,
    role: Role,
    control: &str,
    param: Option<&str>,
    replay: &[String],
) -> Result<ChildNode> {
    let exe = std::env::current_exe().context("locate mava binary")?;
    let mut cmd = Command::new(exe);
    cmd.arg("node")
        .arg("--role")
        .arg(role.arg())
        .arg("--control")
        .arg(control);
    if let Some(p) = param {
        cmd.arg("--param").arg(p);
    }
    for addr in replay {
        cmd.arg("--replay").arg(addr);
    }
    cmd.args(cfg.to_cli_args());
    cmd.stdin(Stdio::null());
    let child = cmd
        .spawn()
        .with_context(|| format!("spawn node {}", role.name()))?;
    Ok(ChildNode { role, child })
}

/// Wait for `name` to register with the control server, polling the
/// already-spawned children so a node that dies *before* saying Hello
/// is reported by name instead of as a bare timeout.
fn wait_registered(
    control: &ControlServer,
    children: &mut [ChildNode],
    name: &str,
    timeout: Duration,
) -> Result<String> {
    let deadline = Instant::now() + timeout;
    loop {
        match control.wait_for(name, Duration::from_millis(50)) {
            Ok(addr) => return Ok(addr),
            Err(_) if Instant::now() < deadline => {
                for c in children.iter_mut() {
                    if let Ok(Some(status)) = c.child.try_wait() {
                        bail!(
                            "node {} exited during startup ({status})",
                            c.role.name()
                        );
                    }
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Spawn the whole program graph into `children`: services first
/// (parameter server, replay shards), then — once their addresses are
/// discovered through the control channel — the workers. Returns the
/// discovered `(param_addr, replay_addrs)` so the supervisor can
/// respawn workers against the same services. Any spawn or
/// registration failure aborts; [`launch`] tears the children down.
fn spawn_graph(
    cfg: &TrainConfig,
    control: &ControlServer,
    children: &mut Vec<ChildNode>,
) -> Result<(String, Vec<String>)> {
    let startup = rpc_timeout(cfg).max(Duration::from_secs(10));
    let shards = cfg.num_executors.max(1);
    children.push(spawn_role(cfg, Role::Param, control.addr(), None, &[])?);
    for k in 0..shards {
        children.push(spawn_role(
            cfg,
            Role::Replay(k),
            control.addr(),
            None,
            &[],
        )?);
    }
    let param_addr =
        wait_registered(control, children, "param_server", startup)?;
    let mut replay_addrs = Vec::with_capacity(shards);
    for k in 0..shards {
        replay_addrs.push(wait_registered(
            control,
            children,
            &Role::Replay(k).name(),
            startup,
        )?);
    }
    println!(
        "services up: param {param_addr}, replay {}",
        replay_addrs.join(" ")
    );

    // workers: trainer, executors, evaluator
    let mut workers = vec![Role::Trainer];
    for k in 0..shards {
        workers.push(Role::Executor(k));
    }
    workers.push(Role::Evaluator);
    for role in workers {
        children.push(spawn_role(
            cfg,
            role,
            control.addr(),
            Some(&param_addr),
            &replay_addrs,
        )?);
        wait_registered(control, children, &role.name(), startup)?;
    }
    println!(
        "launched {} nodes: {}",
        children.len(),
        children
            .iter()
            .map(|c| c.role.name())
            .collect::<Vec<_>>()
            .join(" | ")
    );
    Ok((param_addr, replay_addrs))
}

/// The per-role restart policy (the DESIGN.md §13 matrix): stateful
/// services fail-stop, the trainer restarts (resuming from its
/// checkpoint) and fails the run once its budget is spent, executors
/// and the evaluator restart and then degrade to the survivors.
fn supervision_for(role: Role) -> Supervision {
    match role {
        Role::Param | Role::Replay(_) => Supervision::FailStop,
        Role::Trainer => Supervision::RestartThenFailStop,
        Role::Executor(_) | Role::Evaluator => {
            Supervision::RestartThenDegrade
        }
    }
}

/// The supervisor timing knobs derived from a [`TrainConfig`]:
/// restarts are paced 200ms doubling to 5s under the `max_restarts`
/// budget, a node is stale after 4 missed heartbeats, and wind-down
/// grace is `dist_timeout_s`.
fn supervisor_config(cfg: &TrainConfig) -> SupervisorConfig {
    SupervisorConfig {
        restart: RetryPolicy::new(
            200,
            5_000,
            cfg.max_restarts.min(u32::MAX as u64) as u32,
        ),
        startup: rpc_timeout(cfg).max(Duration::from_secs(10)),
        heartbeat_stale: Duration::from_millis(
            cfg.heartbeat_interval_ms.saturating_mul(4).max(100),
        ),
        wind_down: rpc_timeout(cfg),
    }
}

/// Spawn and supervise the full program graph as separate `mava node`
/// processes under the DESIGN.md §13 restart matrix: a crashed
/// executor / evaluator / trainer is respawned (the trainer resuming
/// from its checkpoint) up to `cfg.max_restarts` times with backoff,
/// a node whose heartbeats go silent is killed and treated the same,
/// and a spent budget degrades the run to the survivors (workers) or
/// fails it (trainer, services). A clean worker exit (completed
/// budget) ends the run; then the driver broadcasts `Stop`, waits up
/// to `cfg.dist_timeout_s` for stragglers (killing any that ignore
/// it) and folds every child's exit into the same typed-outcome error
/// reporting the in-process launcher uses: `Err` names each failed
/// node.
pub fn launch(cfg: &TrainConfig) -> Result<()> {
    let stop = StopSignal::new();
    // supervised binding: a lost control connection is the
    // supervisor's signal to act on, not an immediate program stop
    let mut control =
        ControlServer::bind_supervised(&cfg.bind_host, stop.clone())?;
    let mut children: Vec<ChildNode> = Vec::new();
    let (param_addr, replay_addrs) =
        match spawn_graph(cfg, &control, &mut children) {
            Ok(addrs) => addrs,
            Err(e) => {
                // startup failed: tear everything down before reporting
                for c in children.iter_mut() {
                    let _ = c.child.kill();
                    let _ = c.child.wait();
                }
                control.shutdown();
                return Err(e.context("distributed launch startup"));
            }
        };

    let control_addr = control.addr().to_string();
    let specs: Vec<SupervisedSpec> = children
        .into_iter()
        .map(|c| {
            let role = c.role;
            let cfg = cfg.clone();
            let control_addr = control_addr.clone();
            let param_addr = param_addr.clone();
            let replay_addrs = replay_addrs.clone();
            SupervisedSpec {
                name: role.name(),
                kind: role.kind(),
                supervision: supervision_for(role),
                child: c.child,
                spawn: Box::new(move |_ordinal| {
                    let (param, replay): (Option<&str>, &[String]) =
                        match role {
                            Role::Param | Role::Replay(_) => (None, &[]),
                            _ => (Some(&param_addr), &replay_addrs),
                        };
                    spawn_role(&cfg, role, &control_addr, param, replay)
                        .map(|c| c.child)
                }),
            }
        })
        .collect();

    let report =
        supervise(&control, &stop, specs, &supervisor_config(cfg));
    control.shutdown();
    if report.restarts > 0 {
        println!("supervisor: {} restart(s) performed", report.restarts);
    }
    for o in &report.outcomes {
        if report.degraded.contains(&o.name) {
            println!(
                "  {:<12} DEGRADED (restart budget spent; run \
                 continued on the survivors)",
                o.name
            );
            continue;
        }
        match &o.result {
            Ok(()) => println!("  {:<12} ok", o.name),
            Err(e) => println!("  {:<12} FAILED: {e:#}", o.name),
        }
    }
    outcomes_to_result(&report.outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn role_arg_roundtrip() {
        for role in [
            Role::Param,
            Role::Replay(3),
            Role::Trainer,
            Role::Executor(7),
            Role::Evaluator,
        ] {
            assert_eq!(Role::parse(&role.arg()).unwrap(), role);
        }
        assert!(Role::parse("banana").is_err());
        assert!(Role::parse("executor:x").is_err());
    }

    #[test]
    fn role_names_and_kinds() {
        assert_eq!(Role::Param.name(), "param_server");
        assert_eq!(Role::Replay(2).name(), "replay_2");
        assert_eq!(Role::Executor(0).name(), "executor_0");
        assert_eq!(Role::Param.kind(), NodeKind::ParameterServer);
        assert_eq!(Role::Replay(0).kind(), NodeKind::Replay);
        assert_eq!(Role::Trainer.kind(), NodeKind::Trainer);
        assert_eq!(Role::Evaluator.kind(), NodeKind::Evaluator);
    }

    /// The restart matrix: stateful services fail-stop, the trainer
    /// restarts-then-fails, workers restart-then-degrade.
    #[test]
    fn supervision_matrix_per_role() {
        assert_eq!(supervision_for(Role::Param), Supervision::FailStop);
        assert_eq!(
            supervision_for(Role::Replay(1)),
            Supervision::FailStop
        );
        assert_eq!(
            supervision_for(Role::Trainer),
            Supervision::RestartThenFailStop
        );
        assert_eq!(
            supervision_for(Role::Executor(0)),
            Supervision::RestartThenDegrade
        );
        assert_eq!(
            supervision_for(Role::Evaluator),
            Supervision::RestartThenDegrade
        );
    }

    /// The supervisor knobs derive from the config: the restart budget
    /// is `max_restarts` and staleness is 4 heartbeat intervals.
    #[test]
    fn supervisor_config_derivation() {
        let mut cfg = TrainConfig::default();
        cfg.max_restarts = 3;
        cfg.heartbeat_interval_ms = 50;
        let sup = supervisor_config(&cfg);
        assert_eq!(sup.restart.max_attempts, 3);
        assert_eq!(sup.heartbeat_stale, Duration::from_millis(200));
        assert_eq!(sup.wind_down, rpc_timeout(&cfg));
    }
}
