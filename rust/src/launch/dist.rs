//! Multi-process distributed launch (DESIGN.md §10): the `mava node`
//! and `mava launch` entry points.
//!
//! [`run_node`] runs ONE node of the program graph — a parameter
//! server, a replay shard, the trainer, an executor or the evaluator —
//! in the current process, wired to its peers over the
//! [`crate::net`] protocols. [`launch`] is the driver: it binds a
//! [`ControlServer`], spawns one `mava node` child process per graph
//! node (re-executing the current binary), discovers service
//! addresses through the nodes' `Hello` registrations, supervises the
//! children, and maps every child exit into a typed
//! [`NodeOutcome`] — so a dead remote node trips the stop signal and
//! is reported *by name*, exactly like a dead thread under the
//! in-process [`crate::launch::LocalLauncher`].
//!
//! The node loops themselves are the unchanged structs from
//! [`crate::systems::nodes`]: only the handles differ (remote clients
//! instead of in-process `Arc`s). In-process threads stay the default
//! launcher; this module is opt-in via `mava launch`.

use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::env::wrappers::Fingerprint;
use crate::launch::{
    outcomes_to_result, NodeKind, NodeOutcome, StopSignal,
};
use crate::metrics::{Counters, MovingStats};
use crate::net::control::{ControlClient, ControlServer};
use crate::net::param::{ParamService, RemoteParamClient};
use crate::net::replay::{
    RemoteReplaySampler, RemoteShardClient, ReplayService,
};
use crate::params::{ParamStore, ParameterServer};
use crate::replay::{ItemSink, RateLimiter, Selector, Table};
use crate::runtime::{Engine, Manifest};
use crate::systems::nodes::{
    EnvFactory, ExecutorNode, SystemHandles, TrainerNode,
};
use crate::systems::{env_for_preset, make_vec_evaluator_with, SystemSpec};

/// Which node of the program graph a `mava node` process runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// The versioned parameter server service.
    Param,
    /// Replay shard `k` (one [`Table`] behind a [`ReplayService`]).
    Replay(usize),
    /// The trainer.
    Trainer,
    /// Executor `k` (inserts into replay shard `k`).
    Executor(usize),
    /// The evaluator.
    Evaluator,
}

impl Role {
    /// Parse a `--role` argument: `param`, `replay:K`, `trainer`,
    /// `executor:K` or `evaluator`.
    pub fn parse(s: &str) -> Result<Role> {
        if let Some(k) = s.strip_prefix("replay:") {
            return Ok(Role::Replay(
                k.parse().with_context(|| format!("bad role {s:?}"))?,
            ));
        }
        if let Some(k) = s.strip_prefix("executor:") {
            return Ok(Role::Executor(
                k.parse().with_context(|| format!("bad role {s:?}"))?,
            ));
        }
        match s {
            "param" => Ok(Role::Param),
            "trainer" => Ok(Role::Trainer),
            "evaluator" => Ok(Role::Evaluator),
            other => bail!(
                "unknown role {other:?} (expected param | replay:K | \
                 trainer | executor:K | evaluator)"
            ),
        }
    }

    /// The `--role` argument spelling (inverse of [`Role::parse`]).
    pub fn arg(&self) -> String {
        match self {
            Role::Param => "param".into(),
            Role::Replay(k) => format!("replay:{k}"),
            Role::Trainer => "trainer".into(),
            Role::Executor(k) => format!("executor:{k}"),
            Role::Evaluator => "evaluator".into(),
        }
    }

    /// Node name in the program graph (what failures are reported as).
    pub fn name(&self) -> String {
        match self {
            Role::Param => "param_server".into(),
            Role::Replay(k) => format!("replay_{k}"),
            Role::Trainer => "trainer".into(),
            Role::Executor(k) => format!("executor_{k}"),
            Role::Evaluator => "evaluator".into(),
        }
    }

    /// Node category for the typed outcome channel.
    pub fn kind(&self) -> NodeKind {
        match self {
            Role::Param => NodeKind::ParameterServer,
            Role::Replay(_) => NodeKind::Replay,
            Role::Trainer => NodeKind::Trainer,
            Role::Executor(_) => NodeKind::Executor,
            Role::Evaluator => NodeKind::Evaluator,
        }
    }
}

/// Wiring arguments of one `mava node` process (everything beyond the
/// shared [`TrainConfig`]).
#[derive(Clone, Debug)]
pub struct NodeOpts {
    /// The node to run.
    pub role: Role,
    /// Address of the driver's [`ControlServer`].
    pub control: String,
    /// Parameter-service address (trainer / executor / evaluator).
    pub param: Option<String>,
    /// Replay-service addresses, shard order (trainer gets all,
    /// executor `k` uses entry `k`).
    pub replay: Vec<String>,
}

/// Artifact metadata shared by the replay / trainer / executor roles,
/// resolved exactly like the in-process builder resolves it.
struct TrainMeta {
    spec: &'static SystemSpec,
    train_name: String,
    exec_policy_name: String,
    params0: Vec<f32>,
    opt0: Vec<f32>,
    batch: usize,
    gamma: f32,
    seq_len: usize,
}

fn train_meta(cfg: &TrainConfig) -> Result<TrainMeta> {
    let spec = SystemSpec::parse(&cfg.system)?;
    let prefix = spec.artifact_prefix(&cfg.preset, cfg.arch);
    let train_name = spec.train_artifact(&prefix);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    // executors act at the lowered bucket num_envs rounds UP to
    // (DESIGN.md §11), exactly like the in-process builder
    let ladder = crate::runtime::BucketLadder::from_manifest(
        &manifest,
        &spec.policy_artifact(&prefix),
    )?;
    let (bucket, _pad) =
        ladder.pick(cfg.num_envs_per_executor.max(1))?;
    let exec_policy_name = ladder.artifact_name(bucket);
    let train_art = manifest.get(&train_name)?.clone();
    Ok(TrainMeta {
        spec,
        train_name,
        exec_policy_name,
        params0: manifest.read_init(&train_art, "params0")?,
        opt0: manifest.read_init(&train_art, "opt0")?,
        batch: train_art.meta_usize("batch")?,
        gamma: train_art.meta_f32("gamma")?,
        seq_len: train_art.meta_usize("seq_len")?,
    })
}

/// Per-process [`SystemHandles`] over a remote parameter store. The
/// counters are process-local: in a multi-process run each executor
/// paces itself against `max_env_steps` (there is no global step
/// counter on the wire), so budgets are per-executor.
fn remote_handles(
    server: Arc<dyn ParamStore>,
    stop: StopSignal,
    cfg: &TrainConfig,
) -> SystemHandles {
    SystemHandles {
        server,
        counters: Arc::new(Counters::default()),
        stop,
        evals: Arc::new(Mutex::new(Vec::new())),
        train_returns: Arc::new(Mutex::new(MovingStats::new(64))),
        fingerprint: Fingerprint::new(cfg.eps_start, 0.0),
        started: Instant::now(),
    }
}

fn rpc_timeout(cfg: &TrainConfig) -> Duration {
    Duration::from_secs(cfg.dist_timeout_s.max(1))
}

/// Run one node of the program graph in the current process until the
/// driver broadcasts `Stop` (or the node's own budget completes).
/// This is `mava node`'s body; a returned error makes the process
/// exit non-zero, which the driver maps into the node's
/// [`NodeOutcome`].
pub fn run_node(cfg: &TrainConfig, opts: &NodeOpts) -> Result<()> {
    let stop = StopSignal::new();
    let name = opts.role.name();
    let role_arg = opts.role.arg();
    match opts.role {
        Role::Param => {
            let server = Arc::new(ParameterServer::new(Vec::new()));
            let mut svc = ParamService::bind(server, &cfg.bind_host)?;
            let ctl = ControlClient::connect(
                &opts.control,
                &name,
                &role_arg,
                svc.addr(),
            )?;
            let _watch = ctl.watch_stop(stop.clone())?;
            while !stop.is_stopped() {
                std::thread::sleep(crate::net::frame::POLL_INTERVAL);
            }
            svc.shutdown();
            Ok(())
        }
        Role::Replay(k) => {
            // the same sharding arithmetic as the in-process
            // ShardedTable: capacity and rate limiter are the global
            // figures scaled down to one shard of `num_executors`
            let meta = train_meta(cfg)?;
            let shards = cfg.num_executors.max(1);
            let limiter = RateLimiter::sample_to_insert(
                cfg.samples_per_insert / meta.batch as f64,
                cfg.min_replay,
            )
            .per_shard(shards);
            let table = Arc::new(Table::new(
                (cfg.replay_size / shards).max(1),
                Selector::Uniform,
                limiter,
                (cfg.seed ^ 0x7ab1e)
                    .wrapping_add(0x9e37_79b9u64.wrapping_mul(k as u64 + 1)),
            ));
            let mut svc =
                ReplayService::bind(table.clone(), &cfg.bind_host)?;
            let ctl = ControlClient::connect(
                &opts.control,
                &name,
                &role_arg,
                svc.addr(),
            )?;
            let _watch = ctl.watch_stop(stop.clone())?;
            while !stop.is_stopped() {
                std::thread::sleep(crate::net::frame::POLL_INTERVAL);
            }
            // close BEFORE service shutdown: unblocks rate-limited
            // inserts and makes in-flight samplers see SourceClosed
            table.close();
            svc.shutdown();
            Ok(())
        }
        Role::Trainer => {
            let meta = train_meta(cfg)?;
            let param_addr = opts
                .param
                .as_deref()
                .context("trainer role needs --param ADDR")?;
            anyhow::ensure!(
                !opts.replay.is_empty(),
                "trainer role needs --replay ADDR (one per shard)"
            );
            let server = Arc::new(RemoteParamClient::connect(
                param_addr,
                rpc_timeout(cfg),
            )?);
            let source = Arc::new(RemoteReplaySampler::connect(
                &opts.replay,
                rpc_timeout(cfg),
            )?);
            let ctl =
                ControlClient::connect(&opts.control, &name, &role_arg, "")?;
            let _watch = ctl.watch_stop(stop.clone())?;
            let mut node = TrainerNode {
                spec: meta.spec,
                cfg: cfg.clone(),
                handles: remote_handles(server, stop, cfg),
                train_name: meta.train_name,
                params0: meta.params0,
                opt0: meta.opt0,
                source,
            };
            node.run()
        }
        Role::Executor(k) => {
            let meta = train_meta(cfg)?;
            let param_addr = opts
                .param
                .as_deref()
                .context("executor role needs --param ADDR")?;
            let shard_addr = opts.replay.get(k).with_context(|| {
                format!("executor:{k} needs --replay ADDR #{k}")
            })?;
            let server = Arc::new(RemoteParamClient::connect(
                param_addr,
                rpc_timeout(cfg),
            )?);
            let shard: Arc<dyn ItemSink> =
                Arc::new(RemoteShardClient::connect(shard_addr)?);
            let ctl =
                ControlClient::connect(&opts.control, &name, &role_arg, "")?;
            let _watch = ctl.watch_stop(stop.clone())?;
            let preset = cfg.preset.clone();
            let env_factory: EnvFactory =
                Arc::new(move |s, fp| env_for_preset(&preset, s, fp));
            let spec = meta.spec;
            let (n_step, gamma, seq_len) =
                (cfg.n_step, meta.gamma, meta.seq_len);
            let mut node = ExecutorNode {
                worker: k,
                spec,
                cfg: cfg.clone(),
                handles: remote_handles(server, stop, cfg),
                shard,
                policy_name: meta.exec_policy_name,
                params0: meta.params0,
                env_factory,
                adder_factory: Arc::new(move |shard| {
                    spec.make_adder(shard, n_step, gamma, seq_len)
                }),
            };
            node.run()
        }
        Role::Evaluator => {
            let meta = train_meta(cfg)?;
            let param_addr = opts
                .param
                .as_deref()
                .context("evaluator role needs --param ADDR")?;
            let server = Arc::new(RemoteParamClient::connect(
                param_addr,
                rpc_timeout(cfg),
            )?);
            let ctl =
                ControlClient::connect(&opts.control, &name, &role_arg, "")?;
            let _watch = ctl.watch_stop(stop.clone())?;
            let preset = cfg.preset.clone();
            let env_factory: EnvFactory =
                Arc::new(move |s, fp| env_for_preset(&preset, s, fp));
            run_remote_evaluator(cfg, meta, server, stop, &env_factory)
        }
    }
}

/// The evaluator loop for multi-process runs.
/// [`crate::systems::nodes::EvaluatorNode`] paces itself on the shared
/// env-step counter, which does not exist across
/// processes — here evaluation is paced by *parameter version*
/// instead: a wave runs whenever the parameter server has published
/// something newer than the last wave saw.
fn run_remote_evaluator(
    cfg: &TrainConfig,
    meta: TrainMeta,
    server: Arc<dyn ParamStore>,
    stop: StopSignal,
    env_factory: &EnvFactory,
) -> Result<()> {
    let mut engine = Engine::load(&cfg.artifacts_dir)?;
    let started = Instant::now();
    let mut evaluator = make_vec_evaluator_with(
        &mut engine,
        cfg,
        meta.params0.clone(),
        cfg.eval_episodes,
        cfg.seed ^ 0xe7a1,
        env_factory,
    )?;
    let mut buf = Vec::new();
    while !stop.is_stopped() {
        let synced = server.sync(evaluator.params_version(), &mut buf)?;
        let Some(v) = synced else {
            // nothing new published yet
            std::thread::sleep(Duration::from_millis(50));
            continue;
        };
        evaluator.set_params(v, &buf);
        let returns = evaluator
            .evaluate_until(cfg.eval_episodes, || stop.is_stopped())?;
        if returns.is_empty() {
            continue;
        }
        println!(
            "eval t={:<7.1}s params_v={v:<6} return={:.3}",
            started.elapsed().as_secs_f64(),
            crate::eval::stats::mean(&returns)
        );
    }
    Ok(())
}

/// One spawned `mava node` child under supervision.
struct ChildNode {
    role: Role,
    child: Child,
}

fn spawn_role(
    cfg: &TrainConfig,
    role: Role,
    control: &str,
    param: Option<&str>,
    replay: &[String],
) -> Result<ChildNode> {
    let exe = std::env::current_exe().context("locate mava binary")?;
    let mut cmd = Command::new(exe);
    cmd.arg("node")
        .arg("--role")
        .arg(role.arg())
        .arg("--control")
        .arg(control);
    if let Some(p) = param {
        cmd.arg("--param").arg(p);
    }
    for addr in replay {
        cmd.arg("--replay").arg(addr);
    }
    cmd.args(cfg.to_cli_args());
    cmd.stdin(Stdio::null());
    let child = cmd
        .spawn()
        .with_context(|| format!("spawn node {}", role.name()))?;
    Ok(ChildNode { role, child })
}

/// Wait for `name` to register with the control server, polling the
/// already-spawned children so a node that dies *before* saying Hello
/// is reported by name instead of as a bare timeout.
fn wait_registered(
    control: &ControlServer,
    children: &mut [ChildNode],
    name: &str,
    timeout: Duration,
) -> Result<String> {
    let deadline = Instant::now() + timeout;
    loop {
        match control.wait_for(name, Duration::from_millis(50)) {
            Ok(addr) => return Ok(addr),
            Err(_) if Instant::now() < deadline => {
                for c in children.iter_mut() {
                    if let Ok(Some(status)) = c.child.try_wait() {
                        bail!(
                            "node {} exited during startup ({status})",
                            c.role.name()
                        );
                    }
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Judge one child's exit `status` into the node's typed outcome.
fn judge(
    role: Role,
    status: std::process::ExitStatus,
    lost: bool,
) -> NodeOutcome {
    let result = if status.success() {
        Ok(())
    } else if lost {
        Err(anyhow::anyhow!(
            "control connection lost (process exited: {status})"
        ))
    } else {
        Err(anyhow::anyhow!("process exited: {status}"))
    };
    NodeOutcome { name: role.name(), kind: role.kind(), result }
}

/// Spawn the whole program graph into `children`: services first
/// (parameter server, replay shards), then — once their addresses are
/// discovered through the control channel — the workers. Any spawn or
/// registration failure aborts; [`launch`] tears the children down.
fn spawn_graph(
    cfg: &TrainConfig,
    control: &ControlServer,
    children: &mut Vec<ChildNode>,
) -> Result<()> {
    let startup = rpc_timeout(cfg).max(Duration::from_secs(10));
    let shards = cfg.num_executors.max(1);
    children.push(spawn_role(cfg, Role::Param, control.addr(), None, &[])?);
    for k in 0..shards {
        children.push(spawn_role(
            cfg,
            Role::Replay(k),
            control.addr(),
            None,
            &[],
        )?);
    }
    let param_addr =
        wait_registered(control, children, "param_server", startup)?;
    let mut replay_addrs = Vec::with_capacity(shards);
    for k in 0..shards {
        replay_addrs.push(wait_registered(
            control,
            children,
            &Role::Replay(k).name(),
            startup,
        )?);
    }
    println!(
        "services up: param {param_addr}, replay {}",
        replay_addrs.join(" ")
    );

    // workers: trainer, executors, evaluator
    let mut workers = vec![Role::Trainer];
    for k in 0..shards {
        workers.push(Role::Executor(k));
    }
    workers.push(Role::Evaluator);
    for role in workers {
        children.push(spawn_role(
            cfg,
            role,
            control.addr(),
            Some(&param_addr),
            &replay_addrs,
        )?);
        wait_registered(control, children, &role.name(), startup)?;
    }
    println!(
        "launched {} nodes: {}",
        children.len(),
        children
            .iter()
            .map(|c| c.role.name())
            .collect::<Vec<_>>()
            .join(" | ")
    );
    Ok(())
}

/// Spawn and supervise the full program graph as separate `mava node`
/// processes. Runs until any worker exits (a completed budget or a
/// death — either ends the run), then broadcasts `Stop`, waits up to
/// `cfg.dist_timeout_s` for stragglers (killing any that ignore it)
/// and folds every child's exit into the same typed-outcome error
/// reporting the in-process launcher uses: `Err` names each failed
/// node.
pub fn launch(cfg: &TrainConfig) -> Result<()> {
    let stop = StopSignal::new();
    let mut control = ControlServer::bind(&cfg.bind_host, stop.clone())?;
    let mut children: Vec<ChildNode> = Vec::new();
    if let Err(e) = spawn_graph(cfg, &control, &mut children) {
        // startup failed: tear everything down before reporting
        for c in children.iter_mut() {
            let _ = c.child.kill();
            let _ = c.child.wait();
        }
        control.shutdown();
        return Err(e.context("distributed launch startup"));
    }

    // --- supervise: any child exit (or a lost control connection,
    // which trips `stop` inside the ControlServer) ends the run ---
    let mut early: Vec<Option<std::process::ExitStatus>> =
        children.iter().map(|_| None).collect();
    'supervise: loop {
        std::thread::sleep(crate::net::frame::POLL_INTERVAL);
        for (i, c) in children.iter_mut().enumerate() {
            if let Ok(Some(status)) = c.child.try_wait() {
                early[i] = Some(status);
                println!("node {} exited ({status})", c.role.name());
                break 'supervise;
            }
        }
        if stop.is_stopped() {
            for lost in control.lost_nodes() {
                eprintln!("node {lost} dropped its control connection");
            }
            break;
        }
    }

    // --- wind down: broadcast Stop, give stragglers dist_timeout_s,
    // kill any that ignore it ---
    stop.stop();
    control.stop_all();
    let deadline = Instant::now() + rpc_timeout(cfg);
    let mut outcomes = Vec::with_capacity(children.len());
    for (i, mut c) in children.into_iter().enumerate() {
        let status = match early[i] {
            Some(status) => Some(status),
            None => loop {
                match c.child.try_wait() {
                    Ok(Some(status)) => break Some(status),
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10))
                    }
                    _ => break None,
                }
            },
        };
        let lost = control.lost(&c.role.name());
        outcomes.push(match status {
            Some(status) => judge(c.role, status, lost),
            None => {
                let _ = c.child.kill();
                let _ = c.child.wait();
                NodeOutcome {
                    name: c.role.name(),
                    kind: c.role.kind(),
                    result: Err(anyhow::anyhow!(
                        "node stuck: did not exit within {:?} after \
                         shutdown was requested (process killed)",
                        rpc_timeout(cfg)
                    )),
                }
            }
        });
    }
    control.shutdown();
    for o in &outcomes {
        match &o.result {
            Ok(()) => println!("  {:<12} ok", o.name),
            Err(e) => println!("  {:<12} FAILED: {e:#}", o.name),
        }
    }
    outcomes_to_result(&outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn role_arg_roundtrip() {
        for role in [
            Role::Param,
            Role::Replay(3),
            Role::Trainer,
            Role::Executor(7),
            Role::Evaluator,
        ] {
            assert_eq!(Role::parse(&role.arg()).unwrap(), role);
        }
        assert!(Role::parse("banana").is_err());
        assert!(Role::parse("executor:x").is_err());
    }

    #[test]
    fn role_names_and_kinds() {
        assert_eq!(Role::Param.name(), "param_server");
        assert_eq!(Role::Replay(2).name(), "replay_2");
        assert_eq!(Role::Executor(0).name(), "executor_0");
        assert_eq!(Role::Param.kind(), NodeKind::ParameterServer);
        assert_eq!(Role::Replay(0).kind(), NodeKind::Replay);
        assert_eq!(Role::Trainer.kind(), NodeKind::Trainer);
        assert_eq!(Role::Evaluator.kind(), NodeKind::Evaluator);
    }

    /// `judge` is the driver's exit-status → typed-outcome map: clean
    /// exits are Ok even when the control connection dropped (every
    /// exiting process drops it), unclean exits name the loss.
    #[test]
    fn judge_maps_exit_statuses() {
        use std::process::Command;
        let ok = Command::new("true").status().unwrap();
        let fail = Command::new("false").status().unwrap();
        assert!(judge(Role::Trainer, ok, true).result.is_ok());
        let o = judge(Role::Executor(1), fail, false);
        assert_eq!(o.name, "executor_1");
        assert!(o.result.unwrap_err().to_string().contains("exited"));
        let o = judge(Role::Executor(1), fail, true);
        assert!(o
            .result
            .unwrap_err()
            .to_string()
            .contains("control connection lost"));
    }
}
