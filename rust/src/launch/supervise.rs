//! Supervised restart of `mava node` child processes (DESIGN.md §13).
//!
//! The driver's supervision tree is flat: one supervisor (the `mava
//! launch` process) over every node of the program graph, with a
//! per-role [`Supervision`] policy —
//!
//! * [`Supervision::FailStop`] — stateful services (parameter server,
//!   replay shards). Their in-memory state cannot be respawned, so a
//!   death ends the run immediately, exactly like the pre-supervision
//!   driver.
//! * [`Supervision::RestartThenFailStop`] — the trainer. Respawned
//!   under the restart budget (it resumes from its checkpoint, see
//!   [`crate::systems::TrainerNode`]); a spent budget fails the run,
//!   because nothing trains without it.
//! * [`Supervision::RestartThenDegrade`] — executors and the
//!   evaluator. Respawned under the budget; a spent budget *degrades*
//!   the run to the survivors instead of failing it — losing one
//!   actor's throughput beats losing the experiment.
//!
//! Failure is detected three ways: child exit (`try_wait`), a lost
//! control connection, and heartbeat silence — a node that stops
//! beating for longer than the staleness window while its process
//! still runs is wedged, and is killed and handled by its policy.
//! A *clean* child exit (status 0) is a completed budget and ends the
//! run.
//!
//! The supervisor is deliberately generic over how children are
//! (re)spawned — a [`SupervisedSpec`] carries a closure — so the
//! fault-injection tests drive it with scripted processes instead of
//! real `mava node` graphs.

#![warn(missing_docs)]

use std::process::Child;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::launch::{NodeKind, NodeOutcome, StopSignal};
use crate::net::control::ControlServer;
use crate::net::frame::POLL_INTERVAL;
use crate::net::retry::{Backoff, RetryPolicy};

/// What the supervisor does when a node dies uncleanly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Supervision {
    /// Never restart: the death ends the run as a failure.
    FailStop,
    /// Restart under the budget; a spent budget removes the node from
    /// the run (degraded) without failing it.
    RestartThenDegrade,
    /// Restart under the budget; a spent budget fails the run.
    RestartThenFailStop,
}

/// One node under supervision: its identity, policy, the already
/// running first incarnation, and how to spawn the next one. The
/// closure receives the restart ordinal (1 for the first respawn) so
/// scripted test children can change behaviour across incarnations.
pub struct SupervisedSpec {
    /// Node name — must match the name the node registers with on the
    /// control channel (liveness is looked up by it).
    pub name: String,
    /// Node category for the typed outcome channel.
    pub kind: NodeKind,
    /// Restart policy.
    pub supervision: Supervision,
    /// The running first incarnation.
    pub child: Child,
    /// Spawn incarnation `n` (1-based restart ordinal).
    pub spawn: Box<dyn FnMut(u32) -> Result<Child>>,
}

/// Supervisor timing knobs.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// Restart pacing and budget: `max_attempts` is the per-node
    /// `max_restarts`, the delays pace respawns so a crash loop cannot
    /// spin the machine.
    pub restart: RetryPolicy,
    /// How long a fresh incarnation may take to register on the
    /// control channel before it is presumed wedged at startup.
    pub startup: Duration,
    /// Heartbeat silence window: a registered node not seen within
    /// this window — and still silent one window later — is killed as
    /// wedged. Twice the window total, so a clean exit's connection
    /// teardown is never mistaken for a wedge.
    pub heartbeat_stale: Duration,
    /// Grace between requesting shutdown and killing stragglers.
    pub wind_down: Duration,
}

/// What a supervised run did, per node and overall.
pub struct SuperviseReport {
    /// One typed outcome per spec, in spec order. Degraded nodes
    /// report `Ok` here (their loss was absorbed, not fatal) and are
    /// listed in [`SuperviseReport::degraded`].
    pub outcomes: Vec<NodeOutcome>,
    /// Names of nodes removed from the run after spending their
    /// restart budget.
    pub degraded: Vec<String>,
    /// Total successful respawns across all nodes.
    pub restarts: u64,
}

/// Per-node supervision state.
enum State {
    Running {
        child: Child,
        /// `hello_count` before this incarnation was spawned: the
        /// incarnation has registered once the count exceeds it.
        hellos_at_spawn: u64,
        spawned_at: Instant,
        /// When heartbeat staleness was first observed (kill only if
        /// it persists a full extra window).
        stale_since: Option<Instant>,
    },
    /// Respawn scheduled.
    Waiting { due: Instant },
    /// Budget spent under `RestartThenDegrade`: out of the run.
    Degraded,
    /// Terminal outcome recorded.
    Exited(Result<()>),
}

struct Node {
    name: String,
    kind: NodeKind,
    supervision: Supervision,
    spawn: Box<dyn FnMut(u32) -> Result<Child>>,
    backoff: Backoff,
    restarts: u32,
    state: State,
}

/// What one poll of a node decided.
enum Event {
    None,
    CleanExit,
    Failure(String),
}

/// Supervise `specs` until a node exits cleanly (a completed budget),
/// a fail-stop death occurs, nothing supervisable remains, or `stop`
/// is tripped externally; then wind everything down and report.
///
/// `control` must be a supervised-mode server
/// ([`ControlServer::bind_supervised`]): the supervisor — not the
/// control channel — decides what a lost connection means.
pub fn supervise(
    control: &ControlServer,
    stop: &StopSignal,
    specs: Vec<SupervisedSpec>,
    cfg: &SupervisorConfig,
) -> SuperviseReport {
    let mut nodes: Vec<Node> = specs
        .into_iter()
        .map(|s| Node {
            name: s.name,
            kind: s.kind,
            supervision: s.supervision,
            spawn: s.spawn,
            backoff: Backoff::new(cfg.restart),
            restarts: 0,
            state: State::Running {
                child: s.child,
                hellos_at_spawn: 0,
                spawned_at: Instant::now(),
                stale_since: None,
            },
        })
        .collect();
    let mut total_restarts = 0u64;

    let mut end_run = false;
    while !end_run && !stop.is_stopped() {
        std::thread::sleep(POLL_INTERVAL);
        let mut anything_live = false;
        for node in nodes.iter_mut() {
            let event = match &mut node.state {
                State::Running {
                    child,
                    hellos_at_spawn,
                    spawned_at,
                    stale_since,
                } => {
                    anything_live = true;
                    match child.try_wait() {
                        Ok(Some(status)) if status.success() => {
                            Event::CleanExit
                        }
                        Ok(Some(status)) => Event::Failure(format!(
                            "process exited: {status}"
                        )),
                        _ => {
                            // process alive: check liveness through
                            // the control channel
                            let registered = control
                                .hello_count(&node.name)
                                > *hellos_at_spawn;
                            if !registered {
                                if spawned_at.elapsed() > cfg.startup {
                                    let _ = child.kill();
                                    let _ = child.wait();
                                    Event::Failure(format!(
                                        "did not register within {:?} \
                                         of spawn (killed)",
                                        cfg.startup
                                    ))
                                } else {
                                    Event::None
                                }
                            } else if !control.seen_within(
                                &node.name,
                                cfg.heartbeat_stale,
                            ) {
                                // stale: wedged, or a connection blip.
                                // Kill only if silence persists a full
                                // extra window.
                                match stale_since {
                                    Some(t)
                                        if t.elapsed()
                                            >= cfg.heartbeat_stale =>
                                    {
                                        let _ = child.kill();
                                        let _ = child.wait();
                                        Event::Failure(format!(
                                            "no heartbeat within {:?} \
                                             (killed as wedged)",
                                            cfg.heartbeat_stale
                                        ))
                                    }
                                    Some(_) => Event::None,
                                    None => {
                                        *stale_since =
                                            Some(Instant::now());
                                        Event::None
                                    }
                                }
                            } else {
                                *stale_since = None;
                                Event::None
                            }
                        }
                    }
                }
                State::Waiting { due } => {
                    anything_live = true;
                    if Instant::now() >= *due {
                        let hellos_before =
                            control.hello_count(&node.name);
                        let ordinal = node.restarts;
                        match (node.spawn)(ordinal) {
                            Ok(child) => {
                                eprintln!(
                                    "supervisor: restarted node {} \
                                     (restart #{ordinal})",
                                    node.name
                                );
                                node.state = State::Running {
                                    child,
                                    hellos_at_spawn: hellos_before,
                                    spawned_at: Instant::now(),
                                    stale_since: None,
                                };
                                Event::None
                            }
                            Err(e) => {
                                Event::Failure(format!("respawn: {e:#}"))
                            }
                        }
                    } else {
                        Event::None
                    }
                }
                State::Degraded | State::Exited(_) => Event::None,
            };
            match event {
                Event::None => {}
                Event::CleanExit => {
                    // a completed budget: the run is over
                    node.state = State::Exited(Ok(()));
                    end_run = true;
                }
                Event::Failure(err) => {
                    let delay = if node.supervision
                        == Supervision::FailStop
                    {
                        None
                    } else {
                        node.backoff.next_delay()
                    };
                    match delay {
                        Some(d) => {
                            node.restarts += 1;
                            total_restarts += 1;
                            eprintln!(
                                "supervisor: node {} failed ({err}); \
                                 restart #{} in {d:?}",
                                node.name, node.restarts
                            );
                            node.state =
                                State::Waiting { due: Instant::now() + d };
                        }
                        None if node.supervision
                            == Supervision::RestartThenDegrade =>
                        {
                            eprintln!(
                                "supervisor: node {} failed ({err}); \
                                 restart budget spent — degrading to \
                                 the survivors",
                                node.name
                            );
                            node.state = State::Degraded;
                        }
                        None => {
                            let msg = match node.supervision {
                                Supervision::FailStop => err,
                                _ => format!(
                                    "{err} (restart budget spent)"
                                ),
                            };
                            node.state =
                                State::Exited(Err(anyhow!("{msg}")));
                            end_run = true;
                        }
                    }
                }
            }
        }
        if !anything_live {
            // every node degraded or exited: nothing left to supervise
            break;
        }
    }

    // --- wind down: broadcast Stop, give stragglers the grace
    // period, kill any that ignore it ---
    stop.stop();
    control.stop_all();
    let deadline = Instant::now() + cfg.wind_down;
    let mut outcomes = Vec::with_capacity(nodes.len());
    let mut degraded = Vec::new();
    for node in nodes {
        let result = match node.state {
            State::Exited(result) => result,
            State::Degraded => {
                degraded.push(node.name.clone());
                Ok(())
            }
            State::Waiting { .. } => {
                // a respawn was still pending when the run ended: the
                // node was not running at the end — degraded, not
                // failed
                degraded.push(node.name.clone());
                Ok(())
            }
            State::Running { mut child, .. } => {
                let status = loop {
                    match child.try_wait() {
                        Ok(Some(status)) => break Some(status),
                        Ok(None) if Instant::now() < deadline => {
                            std::thread::sleep(POLL_INTERVAL)
                        }
                        _ => break None,
                    }
                };
                match status {
                    Some(s) if s.success() => Ok(()),
                    Some(s) => Err(anyhow!("process exited: {s}")),
                    None => {
                        let _ = child.kill();
                        let _ = child.wait();
                        Err(anyhow!(
                            "node stuck: did not exit within {:?} \
                             after shutdown was requested (process \
                             killed)",
                            cfg.wind_down
                        ))
                    }
                }
            }
        };
        outcomes.push(NodeOutcome {
            name: node.name,
            kind: node.kind,
            result,
        });
    }
    SuperviseReport { outcomes, degraded, restarts: total_restarts }
}

#[cfg(test)]
#[cfg(unix)]
mod tests {
    use super::*;
    use std::process::Command;

    fn sh(script: &str) -> Child {
        Command::new("sh").arg("-c").arg(script).spawn().unwrap()
    }

    fn quiet_cfg() -> SupervisorConfig {
        SupervisorConfig {
            restart: RetryPolicy::new(1, 4, 2),
            // none of these children register on the control channel,
            // so the startup deadline must stay out of the way
            startup: Duration::from_secs(600),
            heartbeat_stale: Duration::from_secs(600),
            wind_down: Duration::from_secs(10),
        }
    }

    fn server() -> (ControlServer, StopSignal) {
        let stop = StopSignal::new();
        let srv =
            ControlServer::bind_supervised("127.0.0.1", stop.clone())
                .unwrap();
        (srv, stop)
    }

    #[test]
    fn clean_exit_ends_the_run_ok() {
        let (mut control, stop) = server();
        let report = supervise(
            &control,
            &stop,
            vec![SupervisedSpec {
                name: "trainer".into(),
                kind: NodeKind::Trainer,
                supervision: Supervision::RestartThenFailStop,
                child: sh("exit 0"),
                spawn: Box::new(|_| {
                    panic!("a clean exit must not be restarted")
                }),
            }],
            &quiet_cfg(),
        );
        assert_eq!(report.restarts, 0);
        assert!(report.degraded.is_empty());
        assert!(report.outcomes[0].result.is_ok());
        control.shutdown();
    }

    #[test]
    fn crash_loop_spends_budget_then_degrades() {
        let (mut control, stop) = server();
        let report = supervise(
            &control,
            &stop,
            vec![SupervisedSpec {
                name: "executor_0".into(),
                kind: NodeKind::Executor,
                supervision: Supervision::RestartThenDegrade,
                child: sh("exit 3"),
                spawn: Box::new(|_| Ok(sh("exit 3"))),
            }],
            &quiet_cfg(),
        );
        assert_eq!(report.restarts, 2, "max_restarts respawns happened");
        assert_eq!(report.degraded, vec!["executor_0".to_string()]);
        // degradation is absorbed, not a run failure
        assert!(report.outcomes[0].result.is_ok());
        control.shutdown();
    }

    #[test]
    fn trainer_crash_restarts_then_second_incarnation_finishes() {
        let (mut control, stop) = server();
        let report = supervise(
            &control,
            &stop,
            vec![SupervisedSpec {
                name: "trainer".into(),
                kind: NodeKind::Trainer,
                supervision: Supervision::RestartThenFailStop,
                child: sh("exit 7"),
                spawn: Box::new(|_| Ok(sh("exit 0"))),
            }],
            &quiet_cfg(),
        );
        assert_eq!(report.restarts, 1);
        assert!(report.degraded.is_empty());
        assert!(report.outcomes[0].result.is_ok());
        control.shutdown();
    }

    #[test]
    fn failstop_death_fails_the_run_without_restarting() {
        let (mut control, stop) = server();
        let report = supervise(
            &control,
            &stop,
            vec![SupervisedSpec {
                name: "param_server".into(),
                kind: NodeKind::ParameterServer,
                supervision: Supervision::FailStop,
                child: sh("exit 5"),
                spawn: Box::new(|_| {
                    panic!("fail-stop nodes are never respawned")
                }),
            }],
            &quiet_cfg(),
        );
        assert_eq!(report.restarts, 0);
        let err = report.outcomes[0].result.as_ref().unwrap_err();
        assert!(err.to_string().contains("process exited"));
        assert!(stop.is_stopped(), "wind-down trips the stop signal");
        control.shutdown();
    }
}
