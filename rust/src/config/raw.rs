//! Minimal TOML-subset parser: sections, scalar key/values, comments.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    sections: HashMap<String, HashMap<String, String>>,
}

impl RawConfig {
    pub fn parse(text: &str) -> Result<RawConfig> {
        let mut cfg = RawConfig::default();
        let mut section = String::from("");
        for (lineno, line) in text.lines().enumerate() {
            let line = match line.find('#') {
                Some(i) => &line[..i],
                None => line,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let Some(name) = name.strip_suffix(']') else {
                    bail!("line {}: malformed section header", lineno + 1);
                };
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = k.trim().to_string();
            let mut val = v.trim().to_string();
            if val.len() >= 2
                && ((val.starts_with('"') && val.ends_with('"'))
                    || (val.starts_with('\'') && val.ends_with('\'')))
            {
                val = val[1..val.len() - 1].to_string();
            }
            cfg.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<RawConfig> {
        let text = std::fs::read_to_string(path)?;
        RawConfig::parse(&text)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get_str(section, key)?.parse().ok()
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Option<u64> {
        self.get_str(section, key)?.parse().ok()
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get_str(section, key)?.parse().ok()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get_str(section, key)? {
            "true" | "1" | "yes" => Some(true),
            "false" | "0" | "no" => Some(false),
            _ => None,
        }
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_comments() {
        let c = RawConfig::parse(
            "top = 1\n[a]\nx = 2.5 # trailing comment\nname = \"hi\"\n\
             flag = true\n[b]\ny = -3\n",
        )
        .unwrap();
        assert_eq!(c.get_u64("", "top"), Some(1));
        assert_eq!(c.get_f64("a", "x"), Some(2.5));
        assert_eq!(c.get_str("a", "name"), Some("hi"));
        assert_eq!(c.get_bool("a", "flag"), Some(true));
        assert_eq!(c.get_f64("b", "y"), Some(-3.0));
        assert_eq!(c.get_str("a", "missing"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(RawConfig::parse("[oops\n").is_err());
        assert!(RawConfig::parse("keyonly\n").is_err());
    }
}
