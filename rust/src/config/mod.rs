//! Configuration system: a TOML-subset parser (serde is unavailable
//! offline) + the typed experiment config, with CLI overrides.
//!
//! Supported syntax: `[section]` headers, `key = value` pairs (string,
//! float, int, bool), `#` comments. Every training/bench entry point is
//! driven by a [`TrainConfig`], which can be loaded from a file
//! (`configs/*.toml`) and overridden with `--key value` CLI flags.

mod raw;

pub use raw::RawConfig;

use crate::arch::Architecture;
use anyhow::{bail, Context, Result};

/// Full experiment configuration (paper Block 2's program arguments plus
/// the usual hyperparameters).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// System name: madqn | madqn_rec | dial | vdn | qmix | maddpg | mad4pg
    pub system: String,
    /// Artifact preset (DESIGN.md §4): matrix2 | switch3 | smac3m | ...
    pub preset: String,
    pub arch: Architecture,
    /// Number of executor processes (paper `num_executors`).
    pub num_executors: usize,
    /// Environment instances each executor steps per batched policy
    /// call (the vectorized hot path, DESIGN.md §6). Any width up to
    /// the largest lowered policy batch works: the runtime rounds up to
    /// the nearest bucket of the lowered ladder (`POLICY_BATCHES` in
    /// python/compile/model.py) and masks the padding rows
    /// (DESIGN.md §11).
    pub num_envs_per_executor: usize,
    /// Data-parallel trainer lanes (DESIGN.md §11): the assembled batch
    /// is split into this many shards, gradients are computed per lane
    /// via the `_train_dp{D}` artifacts and mean-all-reduced before one
    /// shared `_train_apply` update. 1 = the fused single-device train
    /// step. Validated >= 1; values > 1 must match a lowered
    /// `DP_SHARDS` entry (python/compile/model.py).
    pub num_devices: usize,
    /// Stop after this many total environment steps.
    pub max_env_steps: u64,
    /// Stop after this many trainer steps (0 = unlimited).
    pub max_train_steps: u64,

    // optimisation
    pub lr: f32,
    pub tau: f32,
    pub n_step: usize,

    // exploration
    pub eps_start: f32,
    pub eps_end: f32,
    pub eps_decay_steps: u64,
    pub noise_sigma: f32,

    // replay
    pub replay_size: usize,
    pub min_replay: usize,
    pub samples_per_insert: f64,

    /// Trainer publish cadence: push parameters to the server every K
    /// train steps (K >= 1; the trainer's only steady-state host
    /// download of its device-resident state, DESIGN.md §8).
    pub publish_interval: u64,

    // bookkeeping
    pub seed: u64,
    /// Independent training seeds per scenario in the experiment
    /// harness (`mava experiment`; ignored by `train`/`eval`). The
    /// strata of the stratified bootstrap — see EXPERIMENTS.md.
    pub seeds: usize,
    pub artifacts_dir: String,
    pub log_dir: String,
    /// Evaluator measurement period in env steps (CLI also accepts the
    /// alias `--eval-interval`). Evaluation snapshots *published*
    /// params, so measurements lag training by at most
    /// `publish_interval` trainer steps.
    pub eval_every_steps: u64,
    pub eval_episodes: usize,
    pub params_sync_every: u64,

    // distributed launch (DESIGN.md §10)
    /// Host the `mava launch` driver binds its control / parameter /
    /// replay services on (loopback by default — the multi-process
    /// launcher is single-machine, like Launchpad's
    /// `LOCAL_MULTI_PROCESSING`).
    pub bind_host: String,
    /// Seconds to wait for nodes to wind down after shutdown is
    /// requested before a stuck node is abandoned and reported by name
    /// (threads) or killed (processes).
    pub dist_timeout_s: u64,

    // fault tolerance (DESIGN.md §13)
    /// Liveness beacon period in milliseconds: every `mava node` sends
    /// a heartbeat frame on its control connection at this cadence, and
    /// the supervisor treats a node silent for several periods as
    /// wedged (it is killed and handled by its restart policy).
    /// Validated >= 1.
    pub heartbeat_interval_ms: u64,
    /// Restart budget per node: how many times the supervisor respawns
    /// a crashed restartable node (trainer, executors, evaluator)
    /// before giving up — degrading the run to the survivors
    /// (executors / evaluator) or failing it (trainer). 0 = crashes
    /// are never restarted.
    pub max_restarts: u64,
    /// Trainer checkpoint cadence in train steps: every K steps the
    /// trainer atomically rewrites `{log_dir}/trainer.ckpt`, and a
    /// restarted trainer resumes from it with a monotone param
    /// version. 0 = checkpointing off (a trainer restart retrains from
    /// scratch).
    pub checkpoint_interval: u64,

    // serving (DESIGN.md §12)
    /// `mava serve` coalescing window in microseconds: a partial batch
    /// flushes once its oldest request has waited this long (a full
    /// bucket flushes immediately). Lower = lower tail latency,
    /// higher = bigger batches per artifact call.
    pub serve_deadline_us: u64,
    /// Maximum concurrently open serve sessions (each owns one row of
    /// the recurrent carry for its episode lifetime).
    pub serve_max_sessions: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            system: "madqn".into(),
            preset: "matrix2".into(),
            arch: Architecture::Decentralised,
            num_executors: 1,
            num_envs_per_executor: 1,
            num_devices: 1,
            max_env_steps: 10_000,
            max_train_steps: 0,
            lr: 1e-3,
            tau: 0.01,
            n_step: 1,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_steps: 5_000,
            noise_sigma: 0.2,
            replay_size: 50_000,
            min_replay: 256,
            samples_per_insert: 4.0,
            publish_interval: 1,
            seed: 42,
            seeds: 5,
            artifacts_dir: "artifacts".into(),
            log_dir: "logs".into(),
            eval_every_steps: 1_000,
            eval_episodes: 10,
            params_sync_every: 16,
            bind_host: "127.0.0.1".into(),
            dist_timeout_s: 60,
            heartbeat_interval_ms: 250,
            max_restarts: 2,
            checkpoint_interval: 0,
            serve_deadline_us: 2_000,
            serve_max_sessions: 64,
        }
    }
}

impl TrainConfig {
    /// Apply a parsed config file section (`[train]`) on top of defaults.
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let mut c = TrainConfig::default();
        let sec = "train";
        macro_rules! get {
            ($field:ident, $getter:ident) => {
                if let Some(v) = raw.$getter(sec, stringify!($field)) {
                    c.$field = v.try_into().ok().context(concat!(
                        "bad value for ",
                        stringify!($field)
                    ))?;
                }
            };
        }
        if let Some(v) = raw.get_str(sec, "system") {
            c.system = v.to_string();
        }
        if let Some(v) = raw.get_str(sec, "preset") {
            c.preset = v.to_string();
        }
        if let Some(v) = raw.get_str(sec, "arch") {
            c.arch = Architecture::parse(v)
                .with_context(|| format!("bad arch {v:?}"))?;
        }
        if let Some(v) = raw.get_str(sec, "artifacts_dir") {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = raw.get_str(sec, "log_dir") {
            c.log_dir = v.to_string();
        }
        if let Some(v) = raw.get_str(sec, "bind_host") {
            c.bind_host = v.to_string();
        }
        get!(num_executors, get_usize);
        get!(num_envs_per_executor, get_usize);
        get!(num_devices, get_usize);
        get!(max_env_steps, get_u64);
        get!(max_train_steps, get_u64);
        get!(n_step, get_usize);
        get!(replay_size, get_usize);
        get!(min_replay, get_usize);
        get!(eval_episodes, get_usize);
        get!(seed, get_u64);
        get!(seeds, get_usize);
        get!(eps_decay_steps, get_u64);
        get!(eval_every_steps, get_u64);
        get!(params_sync_every, get_u64);
        get!(publish_interval, get_u64);
        get!(dist_timeout_s, get_u64);
        get!(heartbeat_interval_ms, get_u64);
        get!(max_restarts, get_u64);
        get!(checkpoint_interval, get_u64);
        get!(serve_deadline_us, get_u64);
        get!(serve_max_sessions, get_usize);
        if let Some(v) = raw.get_f64(sec, "lr") {
            c.lr = v as f32;
        }
        if let Some(v) = raw.get_f64(sec, "tau") {
            c.tau = v as f32;
        }
        if let Some(v) = raw.get_f64(sec, "eps_start") {
            c.eps_start = v as f32;
        }
        if let Some(v) = raw.get_f64(sec, "eps_end") {
            c.eps_end = v as f32;
        }
        if let Some(v) = raw.get_f64(sec, "noise_sigma") {
            c.noise_sigma = v as f32;
        }
        if let Some(v) = raw.get_f64(sec, "samples_per_insert") {
            c.samples_per_insert = v;
        }
        c.validate()?;
        Ok(c)
    }

    /// Cross-field / range checks shared by file and CLI loading.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.publish_interval >= 1,
            "publish_interval must be >= 1 (got {})",
            self.publish_interval
        );
        anyhow::ensure!(
            self.seeds >= 1,
            "seeds must be >= 1 (got {})",
            self.seeds
        );
        anyhow::ensure!(
            self.num_devices >= 1,
            "num_devices must be >= 1 (got {})",
            self.num_devices
        );
        anyhow::ensure!(
            self.heartbeat_interval_ms >= 1,
            "heartbeat_interval_ms must be >= 1 (got {})",
            self.heartbeat_interval_ms
        );
        anyhow::ensure!(
            self.serve_deadline_us >= 1,
            "serve_deadline_us must be >= 1 (got {})",
            self.serve_deadline_us
        );
        anyhow::ensure!(
            self.serve_max_sessions >= 1,
            "serve_max_sessions must be >= 1 (got {})",
            self.serve_max_sessions
        );
        Ok(())
    }

    /// Apply `--key value` CLI overrides (after an optional config file).
    pub fn apply_cli(&mut self, args: &[String]) -> Result<()> {
        let mut i = 0;
        while i < args.len() {
            let key = args[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {:?}", args[i]))?;
            let val = args
                .get(i + 1)
                .with_context(|| format!("--{key} requires a value"))?;
            self.set(key, val)?;
            i += 2;
        }
        self.validate()
    }

    /// Set one config key from its string value. Dashes in `key` are
    /// treated as underscores, so `--eval-interval` and
    /// `--eval_interval` are the same flag.
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        let key = key.replace('-', "_");
        match key.as_str() {
            "system" => self.system = val.into(),
            "preset" => self.preset = val.into(),
            "arch" => {
                self.arch = Architecture::parse(val)
                    .with_context(|| format!("bad arch {val:?}"))?
            }
            "num_executors" | "executors" => self.num_executors = val.parse()?,
            "num_envs_per_executor" | "envs_per_executor" => {
                self.num_envs_per_executor = val.parse()?
            }
            "num_devices" | "devices" => {
                self.num_devices = val.parse()?;
                self.validate()?;
            }
            "max_env_steps" | "steps" => self.max_env_steps = val.parse()?,
            "max_train_steps" => self.max_train_steps = val.parse()?,
            "lr" => self.lr = val.parse()?,
            "tau" => self.tau = val.parse()?,
            "n_step" => self.n_step = val.parse()?,
            "eps_start" => self.eps_start = val.parse()?,
            "eps_end" => self.eps_end = val.parse()?,
            "eps_decay_steps" => self.eps_decay_steps = val.parse()?,
            "noise_sigma" => self.noise_sigma = val.parse()?,
            "replay_size" => self.replay_size = val.parse()?,
            "min_replay" => self.min_replay = val.parse()?,
            "samples_per_insert" => self.samples_per_insert = val.parse()?,
            "seed" => self.seed = val.parse()?,
            "seeds" => {
                self.seeds = val.parse()?;
                self.validate()?;
            }
            "artifacts_dir" => self.artifacts_dir = val.into(),
            "log_dir" => self.log_dir = val.into(),
            "eval_every_steps" | "eval_interval" => {
                self.eval_every_steps = val.parse()?
            }
            "eval_episodes" => self.eval_episodes = val.parse()?,
            "params_sync_every" => self.params_sync_every = val.parse()?,
            "bind_host" => self.bind_host = val.into(),
            "dist_timeout_s" => self.dist_timeout_s = val.parse()?,
            "heartbeat_interval_ms" => {
                self.heartbeat_interval_ms = val.parse()?;
                self.validate()?;
            }
            "max_restarts" => self.max_restarts = val.parse()?,
            "checkpoint_interval" => {
                self.checkpoint_interval = val.parse()?
            }
            "serve_deadline_us" => {
                self.serve_deadline_us = val.parse()?;
                self.validate()?;
            }
            "serve_max_sessions" => {
                self.serve_max_sessions = val.parse()?;
                self.validate()?;
            }
            "publish_interval" => {
                self.publish_interval = val.parse()?;
                self.validate()?;
            }
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Serialize every key as `--key value` CLI flags, the inverse of
    /// [`TrainConfig::apply_cli`]. The `mava launch` driver uses this
    /// to hand its (file + CLI merged) configuration to `mava node`
    /// child processes without writing a temp file; round-tripping
    /// through [`TrainConfig::set`] is covered by a unit test.
    pub fn to_cli_args(&self) -> Vec<String> {
        let mut a = Vec::new();
        let mut kv = |k: &str, v: String| {
            a.push(format!("--{k}"));
            a.push(v);
        };
        kv("system", self.system.clone());
        kv("preset", self.preset.clone());
        kv("arch", self.arch.tag().to_string());
        kv("num_executors", self.num_executors.to_string());
        kv(
            "num_envs_per_executor",
            self.num_envs_per_executor.to_string(),
        );
        kv("num_devices", self.num_devices.to_string());
        kv("max_env_steps", self.max_env_steps.to_string());
        kv("max_train_steps", self.max_train_steps.to_string());
        kv("lr", self.lr.to_string());
        kv("tau", self.tau.to_string());
        kv("n_step", self.n_step.to_string());
        kv("eps_start", self.eps_start.to_string());
        kv("eps_end", self.eps_end.to_string());
        kv("eps_decay_steps", self.eps_decay_steps.to_string());
        kv("noise_sigma", self.noise_sigma.to_string());
        kv("replay_size", self.replay_size.to_string());
        kv("min_replay", self.min_replay.to_string());
        kv("samples_per_insert", self.samples_per_insert.to_string());
        kv("publish_interval", self.publish_interval.to_string());
        kv("seed", self.seed.to_string());
        kv("seeds", self.seeds.to_string());
        kv("artifacts_dir", self.artifacts_dir.clone());
        kv("log_dir", self.log_dir.clone());
        kv("eval_every_steps", self.eval_every_steps.to_string());
        kv("eval_episodes", self.eval_episodes.to_string());
        kv("params_sync_every", self.params_sync_every.to_string());
        kv("bind_host", self.bind_host.clone());
        kv("dist_timeout_s", self.dist_timeout_s.to_string());
        kv(
            "heartbeat_interval_ms",
            self.heartbeat_interval_ms.to_string(),
        );
        kv("max_restarts", self.max_restarts.to_string());
        kv("checkpoint_interval", self.checkpoint_interval.to_string());
        kv("serve_deadline_us", self.serve_deadline_us.to_string());
        kv("serve_max_sessions", self.serve_max_sessions.to_string());
        a
    }

    /// Name tag used by artifact lookup, e.g. `smac3m_vdn` or
    /// `spread3_mad4pg_dec`. Delegates to the system's
    /// [`crate::systems::SystemSpec`] (which owns the naming scheme);
    /// unknown system strings keep the plain `{preset}_{system}` tag
    /// so error paths can still print a stable name.
    pub fn artifact_prefix(&self) -> String {
        match crate::systems::SystemSpec::parse(&self.system) {
            Ok(spec) => spec.artifact_prefix(&self.preset, self.arch),
            Err(_) => format!("{}_{}", self.preset, self.system),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_file_then_cli() {
        let raw = RawConfig::parse(
            "# comment\n[train]\nsystem = \"vdn\"\npreset = \"smac3m\"\n\
             lr = 0.0005\nnum_executors = 4\n",
        )
        .unwrap();
        let mut c = TrainConfig::from_raw(&raw).unwrap();
        assert_eq!(c.system, "vdn");
        assert_eq!(c.num_executors, 4);
        assert!((c.lr - 5e-4).abs() < 1e-9);
        c.apply_cli(&[
            "--num_executors".into(),
            "2".into(),
            "--num_envs_per_executor".into(),
            "16".into(),
        ])
        .unwrap();
        assert_eq!(c.num_executors, 2);
        assert_eq!(c.num_envs_per_executor, 16);
        assert_eq!(c.artifact_prefix(), "smac3m_vdn");
    }

    #[test]
    fn actor_critic_prefix_includes_arch() {
        let mut c = TrainConfig::default();
        c.system = "mad4pg".into();
        c.preset = "walker3".into();
        c.arch = Architecture::Centralised;
        assert_eq!(c.artifact_prefix(), "walker3_mad4pg_cen");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = TrainConfig::default();
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn seeds_key_and_eval_interval_alias() {
        let mut c = TrainConfig::default();
        assert_eq!(c.seeds, 5);
        c.set("seeds", "3").unwrap();
        assert_eq!(c.seeds, 3);
        // dash/underscore spellings are interchangeable on the CLI
        c.apply_cli(&[
            "--eval-interval".into(),
            "2500".into(),
            "--eval-episodes".into(),
            "64".into(),
        ])
        .unwrap();
        assert_eq!(c.eval_every_steps, 2500);
        assert_eq!(c.eval_episodes, 64);
        c.set("eval_interval", "100").unwrap();
        assert_eq!(c.eval_every_steps, 100);
        let raw = RawConfig::parse("[train]\nseeds = 7\n").unwrap();
        assert_eq!(TrainConfig::from_raw(&raw).unwrap().seeds, 7);
        let raw = RawConfig::parse("[train]\nseeds = 0\n").unwrap();
        assert!(TrainConfig::from_raw(&raw).is_err());
        assert!(c.set("seeds", "0").is_err());
    }

    /// `to_cli_args` is the exact inverse of `apply_cli`: a config
    /// shipped to a `mava node` child process arrives identical.
    #[test]
    fn cli_args_roundtrip() {
        let c = TrainConfig {
            system: "qmix".into(),
            preset: "smac3m".into(),
            arch: Architecture::Centralised,
            num_executors: 3,
            lr: 2.5e-4,
            samples_per_insert: 0.125,
            bind_host: "0.0.0.0".into(),
            dist_timeout_s: 7,
            ..TrainConfig::default()
        };
        let mut back = TrainConfig::default();
        back.apply_cli(&c.to_cli_args()).unwrap();
        assert_eq!(format!("{back:?}"), format!("{c:?}"));
    }

    #[test]
    fn dist_keys_from_file_and_cli() {
        let raw = RawConfig::parse(
            "[train]\nbind_host = \"10.1.2.3\"\ndist_timeout_s = 9\n",
        )
        .unwrap();
        let c = TrainConfig::from_raw(&raw).unwrap();
        assert_eq!(c.bind_host, "10.1.2.3");
        assert_eq!(c.dist_timeout_s, 9);
        let mut c = TrainConfig::default();
        c.set("dist_timeout_s", "120").unwrap();
        assert_eq!(c.dist_timeout_s, 120);
    }

    #[test]
    fn num_devices_validated_and_aliased() {
        let mut c = TrainConfig::default();
        assert_eq!(c.num_devices, 1);
        c.set("num_devices", "2").unwrap();
        assert_eq!(c.num_devices, 2);
        c.set("devices", "4").unwrap();
        assert_eq!(c.num_devices, 4);
        assert!(c.set("num_devices", "0").is_err());
        let raw = RawConfig::parse("[train]\nnum_devices = 0\n").unwrap();
        assert!(TrainConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[train]\nnum_devices = 2\n").unwrap();
        assert_eq!(TrainConfig::from_raw(&raw).unwrap().num_devices, 2);
        // `to_cli_args` round-trips the new key like every other
        let mut src = TrainConfig::default();
        src.num_devices = 2;
        let mut back = TrainConfig::default();
        back.apply_cli(&src.to_cli_args()).unwrap();
        assert_eq!(back.num_devices, 2);
    }

    #[test]
    fn serve_keys_validated_and_roundtrip() {
        let mut c = TrainConfig::default();
        assert_eq!(c.serve_deadline_us, 2_000);
        assert_eq!(c.serve_max_sessions, 64);
        c.set("serve_deadline_us", "500").unwrap();
        c.set("serve-max-sessions", "8").unwrap();
        assert_eq!((c.serve_deadline_us, c.serve_max_sessions), (500, 8));
        assert!(c.set("serve_deadline_us", "0").is_err());
        assert!(c.set("serve_max_sessions", "0").is_err());
        let raw = RawConfig::parse(
            "[train]\nserve_deadline_us = 750\nserve_max_sessions = 16\n",
        )
        .unwrap();
        let c = TrainConfig::from_raw(&raw).unwrap();
        assert_eq!((c.serve_deadline_us, c.serve_max_sessions), (750, 16));
        let raw =
            RawConfig::parse("[train]\nserve_max_sessions = 0\n").unwrap();
        assert!(TrainConfig::from_raw(&raw).is_err());
        let mut src = TrainConfig::default();
        src.serve_deadline_us = 123;
        src.serve_max_sessions = 9;
        let mut back = TrainConfig::default();
        back.apply_cli(&src.to_cli_args()).unwrap();
        assert_eq!(back.serve_deadline_us, 123);
        assert_eq!(back.serve_max_sessions, 9);
    }

    #[test]
    fn fault_keys_validated_and_roundtrip() {
        let mut c = TrainConfig::default();
        assert_eq!(c.heartbeat_interval_ms, 250);
        assert_eq!(c.max_restarts, 2);
        assert_eq!(c.checkpoint_interval, 0, "checkpointing off by default");
        c.set("heartbeat_interval_ms", "50").unwrap();
        c.set("max-restarts", "5").unwrap();
        c.set("checkpoint_interval", "100").unwrap();
        assert_eq!(
            (c.heartbeat_interval_ms, c.max_restarts, c.checkpoint_interval),
            (50, 5, 100)
        );
        // a zero heartbeat would make staleness detection divide by
        // the interval — rejected; zero restarts / no checkpointing
        // are legitimate choices
        assert!(c.set("heartbeat_interval_ms", "0").is_err());
        assert!(c.set("max_restarts", "0").is_ok());
        assert!(c.set("checkpoint_interval", "0").is_ok());
        let raw = RawConfig::parse(
            "[train]\nheartbeat_interval_ms = 125\nmax_restarts = 1\n\
             checkpoint_interval = 32\n",
        )
        .unwrap();
        let c = TrainConfig::from_raw(&raw).unwrap();
        assert_eq!(
            (c.heartbeat_interval_ms, c.max_restarts, c.checkpoint_interval),
            (125, 1, 32)
        );
        let raw = RawConfig::parse("[train]\nheartbeat_interval_ms = 0\n")
            .unwrap();
        assert!(TrainConfig::from_raw(&raw).is_err());
        let mut src = TrainConfig::default();
        src.heartbeat_interval_ms = 75;
        src.max_restarts = 4;
        src.checkpoint_interval = 64;
        let mut back = TrainConfig::default();
        back.apply_cli(&src.to_cli_args()).unwrap();
        assert_eq!(back.heartbeat_interval_ms, 75);
        assert_eq!(back.max_restarts, 4);
        assert_eq!(back.checkpoint_interval, 64);
    }

    #[test]
    fn publish_interval_validated() {
        let mut c = TrainConfig::default();
        assert_eq!(c.publish_interval, 1);
        c.set("publish_interval", "8").unwrap();
        assert_eq!(c.publish_interval, 8);
        assert!(c.set("publish_interval", "0").is_err());
        let raw =
            RawConfig::parse("[train]\npublish_interval = 0\n").unwrap();
        assert!(TrainConfig::from_raw(&raw).is_err());
        let raw =
            RawConfig::parse("[train]\npublish_interval = 4\n").unwrap();
        assert_eq!(
            TrainConfig::from_raw(&raw).unwrap().publish_interval,
            4
        );
    }
}
