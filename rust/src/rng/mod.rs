//! Deterministic pseudo-random numbers (xoshiro256++ / splitmix64).
//!
//! The offline crate set has no `rand`, so mava-rs carries its own small,
//! well-known generator. Every stochastic component (environments,
//! exploration, replay sampling) takes an explicit seed, which makes full
//! training runs reproducible bit-for-bit across launches.

/// splitmix64 — used to expand a single u64 seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // our n << 2^32 use cases.
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Standard normal via Box-Muller (cached pairs).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * m);
                return u * m;
            }
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn chance(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (stable stream splitting).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            let k = r.below(13);
            assert!(k < 13);
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut seen = [0usize; 5];
        for _ in 0..5_000 {
            seen[r.below(5)] += 1;
        }
        for (i, &c) in seen.iter().enumerate() {
            assert!(c > 800, "bucket {i} underrepresented: {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }
}
