//! Vectorized-executor scaling: steps-per-second vs
//! `(num_executors x num_envs_per_executor)` — the dispatch-amortisation
//! curve behind the paper's Fig 6 (bottom-right) speed argument.
//!
//! Two measurements:
//!
//! 1. **Acting hot path** (no trainer): a `VecExecutor` + `VecEnv` pair
//!    stepping smac3m with one batched policy call per vector step, for
//!    `B ∈ {1, 4, 16}` — measured BOTH through the legacy per-TimeStep
//!    path and the SoA `VecStepBuf` path (zero steady-state allocation,
//!    device-resident carry). Per-executor env-steps/s should grow
//!    ~linearly until the policy kernel saturates; the acceptance bar
//!    is SoA B=16 achieving >= 3x the SoA B=1 per-executor throughput.
//! 2. **End-to-end training throughput**: `train()` on matrix2 madqn
//!    over the `{1, 2} executors x {1, 4, 16} envs` grid with a fixed
//!    wall budget, reporting total env-steps/s (replay sharding keeps
//!    executors lock-free on the insert path).
//!
//! Requires `make artifacts` (including the `*_policy_b{4,16}` batched
//! variants). Scale with MAVA_BENCH_SCALE. Besides the grep-able
//! `curve` rows, the run serialises every measured rate as
//! `BENCH_vector_scaling.json` AND the legacy-vs-SoA comparison as
//! `BENCH_executor_hotpath.json` (both in the versioned schema of
//! `bench/report.rs` — validate with `mava check-bench`).

use mava::bench::report::{throughput_report, write_report};
use mava::bench::{self, curve_row, report, section, time};
use mava::config::TrainConfig;
use mava::env::VecEnv;
use mava::runtime::{Engine, Manifest};
use mava::systems::{self, SystemKind, VecExecutor};

const BATCHES: [usize; 3] = [1, 4, 16];

fn policy_name(b: usize) -> String {
    if b == 1 {
        "smac3m_madqn_policy".into()
    } else {
        format!("smac3m_madqn_policy_b{b}")
    }
}

fn make_pair(
    engine: &mut Engine,
    params: &[f32],
    b: usize,
) -> anyhow::Result<(VecExecutor, VecEnv)> {
    let artifact = engine.artifact(&policy_name(b))?;
    let executor =
        VecExecutor::new(SystemKind::Madqn, artifact, params.to_vec(), 7)?;
    let mut instances = Vec::with_capacity(b);
    for i in 0..b {
        instances.push(systems::env_for_preset(
            "smac3m",
            100 + i as u64,
            None,
        )?);
    }
    Ok((executor, VecEnv::new(instances)?))
}

/// Measure one configuration of the acting loop; `soa` picks the
/// struct-of-arrays zero-allocation path vs the legacy per-TimeStep
/// path. Returns env steps/s.
fn measure_acting(
    engine: &mut Engine,
    params: &[f32],
    b: usize,
    soa: bool,
) -> anyhow::Result<f64> {
    let (mut executor, mut venv) = make_pair(engine, params, b)?;
    let iters = (2_000.0 * bench::scale()) as u64;
    let s = if soa {
        let mut cur = venv.make_buf();
        let mut next = venv.make_buf();
        let mut abuf = venv.make_action_buf();
        venv.reset_into(&mut cur);
        time(50, iters, move || {
            executor
                .select_actions_into(&cur, 0.1, 0.0, &mut abuf)
                .unwrap();
            venv.step_into(&abuf, &mut next);
            for row in 0..next.num_envs() {
                if next.step_type(row) == mava::StepType::First {
                    executor.reset_instance(row);
                }
            }
            std::mem::swap(&mut cur, &mut next);
        })
    } else {
        let mut vs = venv.reset();
        time(50, iters, move || {
            let actions =
                executor.select_actions_vec(&vs, 0.1, 0.0).unwrap();
            vs = venv.step(&actions);
        })
    };
    let tag = if soa { "soa" } else { "legacy" };
    report(&format!("vec_step_smac3m_madqn_{tag}_b{b}"), &s);
    Ok(s.per_sec() * b as f64)
}

fn bench_acting_hot_path(
    series: &mut Vec<(String, f64, String)>,
    hotpath: &mut Vec<(String, f64, String)>,
) -> anyhow::Result<()> {
    section("acting hot path: env steps/s per executor vs B (legacy vs SoA)");
    let mut engine = Engine::load("artifacts")?;
    let params = engine.read_init("smac3m_madqn_train", "params0")?;
    let mut rates = Vec::new();
    for b in BATCHES {
        let legacy = measure_acting(&mut engine, &params, b, false)?;
        let soa = measure_acting(&mut engine, &params, b, true)?;
        curve_row(
            "vector_scaling",
            "acting_env_steps_per_sec",
            b as f64,
            soa,
        );
        rates.push((b, legacy, soa));
        series.push((format!("acting_b{b}"), soa, "env_steps/s".into()));
        // the ISSUE-4 acceptance pair: legacy vs SoA at B ∈ {4, 16}
        if b > 1 {
            hotpath.push((
                format!("legacy_b{b}"),
                legacy,
                "env_steps/s".into(),
            ));
            hotpath.push((format!("soa_b{b}"), soa, "env_steps/s".into()));
        }
    }
    let base = rates[0].2;
    println!(
        "\nper-executor acting throughput (one PJRT call per vector step):"
    );
    for (b, legacy, soa) in &rates {
        println!(
            "  B={b:<3} legacy {legacy:>10.0}  soa {soa:>10.0} env steps/s \
             ({:>5.2}x legacy, {:>5.2}x vs soa B=1)",
            soa / legacy,
            soa / base
        );
    }
    let b16 = rates.last().unwrap().2;
    println!(
        "speedup check: SoA B=16 is {:.2}x SoA B=1 ({})",
        b16 / base,
        if b16 >= 3.0 * base { "PASS >= 3x" } else { "BELOW 3x" }
    );
    Ok(())
}

fn train_cfg(executors: usize, envs: usize) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = "madqn".into();
    c.preset = "matrix2".into();
    c.num_executors = executors;
    c.num_envs_per_executor = envs;
    c.max_env_steps = u64::MAX / 2; // wall clock is the budget
    c.min_replay = 64;
    // throughput bench: a loose sample:insert ratio so the acting path,
    // not trainer flow control, is the binding constraint
    c.samples_per_insert = 0.125;
    c.replay_size = 200_000;
    c.eval_every_steps = u64::MAX / 2; // evaluator mostly idle
    c.eval_episodes = 1;
    c.seed = 11;
    c
}

fn bench_end_to_end(
    series: &mut Vec<(String, f64, String)>,
) -> anyhow::Result<()> {
    section("end-to-end: total env steps/s vs executors x envs");
    let budget_s = (15.0 * bench::scale()) as u64;
    let mut baseline = None;
    for executors in [1usize, 2] {
        for envs in BATCHES {
            let r = systems::train(
                &train_cfg(executors, envs),
                Some(std::time::Duration::from_secs(budget_s)),
            )?;
            let rate = r.env_steps as f64 / r.wall_s.max(1e-9);
            let x = (executors * envs) as f64;
            curve_row(
                "vector_scaling",
                &format!("train_env_steps_per_sec_exec{executors}"),
                x,
                rate,
            );
            let base = *baseline.get_or_insert(rate);
            series.push((
                format!("train_exec{executors}_b{envs}"),
                rate,
                "env_steps/s".into(),
            ));
            println!(
                "  {executors} executor(s) x B={envs:<3} {:>9} env steps in \
                 {:>5.1}s = {:>9.0} steps/s ({:>5.2}x)  [{} train steps]",
                r.env_steps,
                r.wall_s,
                rate,
                rate / base,
                r.train_steps,
            );
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let Ok(manifest) = Manifest::load("artifacts") else {
        println!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };
    if manifest.get(&policy_name(16)).is_err() {
        println!(
            "batched policy artifacts missing (stale artifacts dir); \
             re-run `make artifacts` to lower the *_policy_b{{4,16}} \
             variants"
        );
        return Ok(());
    }
    let mut series = Vec::new();
    let mut hotpath = Vec::new();
    bench_acting_hot_path(&mut series, &mut hotpath)?;
    bench_end_to_end(&mut series)?;
    let json = throughput_report("vector_scaling", &series);
    let path =
        write_report(std::path::Path::new("."), "vector_scaling", &json)?;
    println!("\nwrote {}", path.display());
    // the ISSUE-4 perf artifact: legacy vs SoA at B ∈ {4, 16}, gated by
    // `mava check-bench` in CI like every other BENCH_*.json
    let json = throughput_report("executor_hotpath", &hotpath);
    let path =
        write_report(std::path::Path::new("."), "executor_hotpath", &json)?;
    println!("wrote {}", path.display());
    Ok(())
}
