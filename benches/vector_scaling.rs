//! Vectorized-executor scaling: steps-per-second vs
//! `(num_executors x num_envs_per_executor)` — the dispatch-amortisation
//! curve behind the paper's Fig 6 (bottom-right) speed argument.
//!
//! Two measurements:
//!
//! 1. **Acting hot path** (no trainer): a `VecExecutor` + `VecEnv` pair
//!    stepping smac3m with one batched policy call per vector step, for
//!    widths spanning the lowered bucket ladder INCLUDING non-bucket
//!    widths (3, 12) that round up with padding rows masked out
//!    (DESIGN.md §11) — measured through the legacy per-TimeStep path
//!    (exact buckets only; it cannot pad) and the SoA `VecStepBuf`
//!    path (zero steady-state allocation, device-resident carry).
//!    Per-executor env-steps/s should grow ~linearly until the policy
//!    kernel saturates; the acceptance bar is SoA B=16 achieving
//!    >= 3x the SoA B=1 per-executor throughput.
//! 2. **End-to-end training throughput**: `train()` on matrix2 madqn
//!    over the `{1, 2} executors x {1, 4, 16} envs` grid with a fixed
//!    wall budget, reporting total env-steps/s (replay sharding keeps
//!    executors lock-free on the insert path).
//!
//! Requires `make artifacts` (which lowers the full `POLICY_BATCHES`
//! bucket ladder). Scale with MAVA_BENCH_SCALE. Besides the grep-able
//! `curve` rows, the run serialises every measured rate as
//! `BENCH_vector_scaling.json` AND the legacy-vs-SoA comparison as
//! `BENCH_executor_hotpath.json` (both in the versioned schema of
//! `bench/report.rs` — validate with `mava check-bench`; bucketed
//! rows carry the `bucket` axis).

use mava::bench::report::{
    throughput_report_rows, write_report, ThroughputRow,
};
use mava::bench::{self, curve_row, report, section, time};
use mava::config::TrainConfig;
use mava::env::VecEnv;
use mava::runtime::{BucketLadder, Engine, Manifest};
use mava::systems::{self, SystemKind, VecExecutor};

const BASE_POLICY: &str = "smac3m_madqn_policy";

/// Acting widths: exact buckets (1, 4, 16) plus padded widths (3, 12)
/// that round up to the next lowered bucket.
const WIDTHS: [usize; 5] = [1, 3, 4, 12, 16];

/// End-to-end grid widths (exact buckets, matching earlier reports).
const TRAIN_WIDTHS: [usize; 3] = [1, 4, 16];

/// Build an `n`-wide executor/env pair: the policy artifact is the
/// lowered bucket `n` rounds up to; the executor masks the padding
/// rows out of action selection. Returns the pair and the bucket.
fn make_pair(
    engine: &mut Engine,
    params: &[f32],
    n: usize,
) -> anyhow::Result<(VecExecutor, VecEnv, usize)> {
    let ladder = BucketLadder::from_manifest(&engine.manifest, BASE_POLICY)?;
    let (bucket, _pad) = ladder.pick(n)?;
    let artifact = engine.artifact(&ladder.artifact_name(bucket))?;
    let mut executor =
        VecExecutor::new(SystemKind::Madqn, artifact, params.to_vec(), 7)?;
    executor.set_active_rows(n)?;
    let mut instances = Vec::with_capacity(n);
    for i in 0..n {
        instances.push(systems::env_for_preset(
            "smac3m",
            100 + i as u64,
            None,
        )?);
    }
    Ok((executor, VecEnv::new(instances)?, bucket))
}

/// Measure one configuration of the acting loop; `soa` picks the
/// struct-of-arrays zero-allocation path vs the legacy per-TimeStep
/// path (which needs `n` == the bucket). Returns `(env steps/s,
/// bucket)`.
fn measure_acting(
    engine: &mut Engine,
    params: &[f32],
    n: usize,
    soa: bool,
) -> anyhow::Result<(f64, usize)> {
    let (mut executor, mut venv, bucket) = make_pair(engine, params, n)?;
    let iters = (2_000.0 * bench::scale()) as u64;
    let s = if soa {
        let mut cur = venv.make_buf_padded(bucket);
        let mut next = venv.make_buf_padded(bucket);
        let mut abuf = venv.make_action_buf_padded(bucket);
        venv.reset_into(&mut cur);
        time(50, iters, move || {
            executor
                .select_actions_into(&cur, 0.1, 0.0, &mut abuf)
                .unwrap();
            venv.step_into(&abuf, &mut next);
            for row in 0..venv.num_envs() {
                if next.step_type(row) == mava::StepType::First {
                    executor.reset_instance(row);
                }
            }
            std::mem::swap(&mut cur, &mut next);
        })
    } else {
        assert_eq!(n, bucket, "legacy path cannot pad");
        let mut vs = venv.reset();
        time(50, iters, move || {
            let actions =
                executor.select_actions_vec(&vs, 0.1, 0.0).unwrap();
            vs = venv.step(&actions);
        })
    };
    let tag = if soa { "soa" } else { "legacy" };
    report(&format!("vec_step_smac3m_madqn_{tag}_n{n}"), &s);
    Ok((s.per_sec() * n as f64, bucket))
}

fn bench_acting_hot_path(
    series: &mut Vec<ThroughputRow>,
    hotpath: &mut Vec<ThroughputRow>,
) -> anyhow::Result<()> {
    section(
        "acting hot path: env steps/s per executor vs width \
         (legacy vs SoA, padded widths round up the bucket ladder)",
    );
    let mut engine = Engine::load("artifacts")?;
    let params = engine.read_init("smac3m_madqn_train", "params0")?;
    let mut rates = Vec::new();
    for n in WIDTHS {
        let (soa, bucket) = measure_acting(&mut engine, &params, n, true)?;
        // the legacy AoS path has no padding mask: only exact buckets
        let legacy = if n == bucket {
            Some(measure_acting(&mut engine, &params, n, false)?.0)
        } else {
            None
        };
        curve_row(
            "vector_scaling",
            "acting_env_steps_per_sec",
            n as f64,
            soa,
        );
        rates.push((n, bucket, legacy, soa));
        series.push(
            ThroughputRow::new(
                format!("acting_n{n}"),
                soa,
                "env_steps/s",
            )
            .with_bucket(bucket as u64),
        );
        // the ISSUE-4 acceptance pair: legacy vs SoA at exact buckets
        if n > 1 {
            if let Some(legacy) = legacy {
                hotpath.push(
                    ThroughputRow::new(
                        format!("legacy_b{n}"),
                        legacy,
                        "env_steps/s",
                    )
                    .with_bucket(bucket as u64),
                );
                hotpath.push(
                    ThroughputRow::new(
                        format!("soa_b{n}"),
                        soa,
                        "env_steps/s",
                    )
                    .with_bucket(bucket as u64),
                );
            }
        }
    }
    let base = rates[0].3;
    println!(
        "\nper-executor acting throughput (one PJRT call per vector step):"
    );
    for (n, bucket, legacy, soa) in &rates {
        let legacy_txt = match legacy {
            Some(l) => format!("legacy {l:>10.0}"),
            None => format!("padded to b{bucket:<3}   "),
        };
        println!(
            "  n={n:<3} {legacy_txt}  soa {soa:>10.0} env steps/s \
             ({:>5.2}x vs soa n=1)",
            soa / base
        );
    }
    let b16 = rates.last().unwrap().3;
    println!(
        "speedup check: SoA B=16 is {:.2}x SoA B=1 ({})",
        b16 / base,
        if b16 >= 3.0 * base { "PASS >= 3x" } else { "BELOW 3x" }
    );
    Ok(())
}

fn train_cfg(executors: usize, envs: usize) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = "madqn".into();
    c.preset = "matrix2".into();
    c.num_executors = executors;
    c.num_envs_per_executor = envs;
    c.max_env_steps = u64::MAX / 2; // wall clock is the budget
    c.min_replay = 64;
    // throughput bench: a loose sample:insert ratio so the acting path,
    // not trainer flow control, is the binding constraint
    c.samples_per_insert = 0.125;
    c.replay_size = 200_000;
    c.eval_every_steps = u64::MAX / 2; // evaluator mostly idle
    c.eval_episodes = 1;
    c.seed = 11;
    c
}

fn bench_end_to_end(
    series: &mut Vec<ThroughputRow>,
) -> anyhow::Result<()> {
    section("end-to-end: total env steps/s vs executors x envs");
    let budget_s = (15.0 * bench::scale()) as u64;
    let mut baseline = None;
    for executors in [1usize, 2] {
        for envs in TRAIN_WIDTHS {
            let r = systems::train(
                &train_cfg(executors, envs),
                Some(std::time::Duration::from_secs(budget_s)),
            )?;
            let rate = r.env_steps as f64 / r.wall_s.max(1e-9);
            let x = (executors * envs) as f64;
            curve_row(
                "vector_scaling",
                &format!("train_env_steps_per_sec_exec{executors}"),
                x,
                rate,
            );
            let base = *baseline.get_or_insert(rate);
            series.push(
                ThroughputRow::new(
                    format!("train_exec{executors}_b{envs}"),
                    rate,
                    "env_steps/s",
                )
                .with_bucket(envs as u64)
                .with_devices(1),
            );
            println!(
                "  {executors} executor(s) x B={envs:<3} {:>9} env steps in \
                 {:>5.1}s = {:>9.0} steps/s ({:>5.2}x)  [{} train steps]",
                r.env_steps,
                r.wall_s,
                rate,
                rate / base,
                r.train_steps,
            );
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let Ok(manifest) = Manifest::load("artifacts") else {
        println!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };
    // report the ladder the manifest ACTUALLY holds, not a hard-coded
    // batch list: a stale artifacts dir names exactly what is missing
    match BucketLadder::from_manifest(&manifest, BASE_POLICY) {
        Ok(ladder) if ladder.max_bucket() >= *WIDTHS.last().unwrap() => {}
        Ok(ladder) => {
            println!(
                "lowered policy ladder for {BASE_POLICY} is [{}], but \
                 this bench needs buckets up to {}; re-run \
                 `make artifacts` to lower the full POLICY_BATCHES \
                 ladder",
                ladder.describe(),
                WIDTHS.last().unwrap()
            );
            return Ok(());
        }
        Err(e) => {
            println!("{e:#}");
            return Ok(());
        }
    }
    let mut series = Vec::new();
    let mut hotpath = Vec::new();
    bench_acting_hot_path(&mut series, &mut hotpath)?;
    bench_end_to_end(&mut series)?;
    let json = throughput_report_rows("vector_scaling", &series);
    let path =
        write_report(std::path::Path::new("."), "vector_scaling", &json)?;
    println!("\nwrote {}", path.display());
    // the ISSUE-4 perf artifact: legacy vs SoA at B ∈ {4, 16}, gated by
    // `mava check-bench` in CI like every other BENCH_*.json
    let json = throughput_report_rows("executor_hotpath", &hotpath);
    let path =
        write_report(std::path::Path::new("."), "executor_hotpath", &json)?;
    println!("wrote {}", path.display());
    Ok(())
}
