//! `mava serve` request-latency bench: the deadline-vs-batching
//! tradeoff of the coalescing core (DESIGN.md §12).
//!
//! Drives [`ServeCore`] directly (mock policy, real [`SystemClock`])
//! at three offered loads. One client can never fill a bucket, so its
//! p50 sits at ~`serve_deadline_us`; at a load matching the largest
//! lowered bucket the flush is size-triggered and latency collapses
//! to the inference cost. Emits a schema-versioned `latency` report
//! (`BENCH_serve_latency.json`) gated by `mava check-bench` like every
//! other bench artifact (EXPERIMENTS.md §2).

use std::sync::Arc;

use mava::bench::report::{latency_report, write_report, LatencyRow};
use mava::bench::{scale, section};
use mava::serve::{Clock, MockBackend, ServeCore, SystemClock};

const DEADLINE_US: u64 = 2_000;
const OBS_WIDTH: usize = 4;

/// Nearest-rank percentile of an ascending-sorted sample.
fn pct(sorted: &[u64], q: f64) -> f64 {
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx] as f64
}

fn bench_load(clients: usize, rounds: usize) -> LatencyRow {
    let clock = Arc::new(SystemClock::new());
    let backend = MockBackend::new(OBS_WIDTH, 1, 2, &[1, 2, 4, 8, 16]);
    let mut core = ServeCore::new(backend, clock.clone(), 32, DEADLINE_US);
    let sessions: Vec<u64> =
        (0..clients).map(|_| core.open_session().unwrap()).collect();
    let mut lat = Vec::with_capacity(clients * rounds);
    for _ in 0..rounds {
        let t0 = clock.now_us();
        for &s in &sessions {
            core.submit(s, vec![1.0; OBS_WIDTH]).unwrap();
        }
        let mut got = 0;
        while got < sessions.len() {
            let responses = core.step().unwrap();
            let now = clock.now_us();
            for _ in &responses {
                lat.push(now - t0);
                got += 1;
            }
            if responses.is_empty() {
                std::thread::yield_now();
            }
        }
    }
    lat.sort_unstable();
    let count = lat.len() as u64;
    LatencyRow {
        name: format!("load_{clients}_clients"),
        count,
        p50_us: pct(&lat, 0.50),
        p99_us: pct(&lat, 0.99),
        mean_us: lat.iter().sum::<u64>() as f64 / count as f64,
    }
}

fn main() -> anyhow::Result<()> {
    section("serve request latency (mock policy, real clock)");
    let rounds = (300.0 * scale()) as usize;
    let mut rows = Vec::new();
    // 1 = deadline-bound, 8 = partial coalescing, 16 = full buckets
    for &clients in &[1usize, 8, 16] {
        let row = bench_load(clients, rounds);
        println!(
            "serve {:<18} n={:<6} p50 {:>9.0} us  p99 {:>9.0} us  \
             mean {:>9.0} us",
            row.name, row.count, row.p50_us, row.p99_us, row.mean_us
        );
        rows.push(row);
    }
    let json = latency_report("serve_latency", &rows);
    let path = write_report(std::path::Path::new("."), "serve_latency", &json)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
