//! Ablations over the design choices DESIGN.md calls out:
//!   1. n-step returns for MAD4PG (n = 1 vs 5)
//!   2. replay stabilisation fingerprints on smac_lite MADQN
//!   3. samples-per-insert rate limiting (2 vs 16)
//!   4. networked vs centralised vs decentralised critics on spread
//!
//! Scale with MAVA_BENCH_SCALE (default: short 15-20k-step curves).

use mava::arch::Architecture;
use mava::bench;
use mava::config::TrainConfig;

fn base(system: &str, preset: &str, steps: u64, seed: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = system.into();
    c.preset = preset.into();
    c.num_executors = 2;
    c.max_env_steps = steps;
    c.min_replay = 500;
    c.samples_per_insert = 8.0;
    c.lr = 1e-3;
    c.eval_every_steps = (steps / 8).max(1);
    c.eval_episodes = 8;
    c.seed = seed;
    c
}

fn main() -> anyhow::Result<()> {
    let steps = (16_000.0 * bench::scale()) as u64;

    bench::section("ablation: MAD4PG n-step (spread3)");
    for n_step in [1usize, 5] {
        let mut c = base("mad4pg", "spread3", steps, 21);
        c.n_step = n_step;
        c.noise_sigma = 0.3;
        bench::figure_run("abl_nstep", &format!("n{n_step}"), &c, 600)?;
    }

    bench::section("ablation: fingerprint stabilisation (smac MADQN)");
    for (preset, label) in [("smac3m", "plain"), ("smac3m_fp", "fingerprint")] {
        let mut c = base("madqn", preset, steps, 23);
        c.eps_decay_steps = steps / 2;
        bench::figure_run("abl_fingerprint", label, &c, 600)?;
    }

    bench::section("ablation: samples-per-insert rate limit (vdn smac)");
    for spi in [8.0f64, 64.0] {
        let mut c = base("vdn", "smac3m", steps, 25);
        c.samples_per_insert = spi;
        c.eps_decay_steps = steps / 2;
        let r = bench::figure_run(
            "abl_spi",
            &format!("spi{spi}"),
            &c,
            600,
        )?;
        println!(
            "  spi={spi}: {} train steps for {} env steps",
            r.train_steps, r.env_steps
        );
    }

    bench::section("ablation: critic architecture (mad4pg spread3)");
    for arch in [
        Architecture::Decentralised,
        Architecture::Centralised,
        Architecture::Networked,
    ] {
        let mut c = base("mad4pg", "spread3", steps, 27);
        c.arch = arch;
        c.n_step = 5;
        c.noise_sigma = 0.3;
        bench::figure_run("abl_arch", arch.tag(), &c, 600)?;
    }
    Ok(())
}
