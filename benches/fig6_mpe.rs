//! Paper Figure 6 (top-right): MPE — decentralised MAD4PG vs MADDPG with
//! weight-sharing-free independent critics on simple_spread, and the
//! centralised pair on simple_speaker_listener. Expected shape: both
//! systems reach similar mean episode return (paper: "similar to
//! previously reported performances").
//!
//! Scale with MAVA_BENCH_SCALE (default: 30k env steps per run).

use mava::bench;
use mava::config::TrainConfig;
use mava::arch::Architecture;

fn cfg(system: &str, preset: &str, arch: Architecture, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = system.into();
    c.preset = preset.into();
    c.arch = arch;
    c.num_executors = 2;
    c.max_env_steps = steps;
    c.n_step = if system == "mad4pg" { 5 } else { 1 };
    c.noise_sigma = 0.3;
    c.min_replay = 1_000;
    c.replay_size = 100_000;
    c.samples_per_insert = 32.0;
    c.lr = 1e-3;
    c.tau = 0.01;
    c.eval_every_steps = (steps / 10).max(1);
    c.eval_episodes = 10;
    c.seed = 5;
    c
}

fn main() -> anyhow::Result<()> {
    let steps = (30_000.0 * bench::scale()) as u64;
    bench::section("Fig 6 (top-right): MPE spread — MADDPG vs MAD4PG");
    let d4 = bench::figure_run(
        "fig6_spread",
        "mad4pg",
        &cfg("mad4pg", "spread3", Architecture::Decentralised, steps),
        900,
    )?;
    let dd = bench::figure_run(
        "fig6_spread",
        "maddpg",
        &cfg("maddpg", "spread3", Architecture::Decentralised, steps),
        900,
    )?;
    bench::section("Fig 6 (top-right): MPE speaker-listener (centralised)");
    let d4s = bench::figure_run(
        "fig6_speaker",
        "mad4pg",
        &cfg("mad4pg", "speaker2", Architecture::Centralised, steps),
        900,
    )?;
    let dds = bench::figure_run(
        "fig6_speaker",
        "maddpg",
        &cfg("maddpg", "speaker2", Architecture::Centralised, steps),
        900,
    )?;
    println!(
        "\nshape check (both systems solve both envs, similar returns):\n\
         spread:  mad4pg {:.1} vs maddpg {:.1}\n\
         speaker: mad4pg {:.1} vs maddpg {:.1}",
        d4.best_return().unwrap_or(f32::NAN),
        dd.best_return().unwrap_or(f32::NAN),
        d4s.best_return().unwrap_or(f32::NAN),
        dds.best_return().unwrap_or(f32::NAN)
    );
    Ok(())
}
