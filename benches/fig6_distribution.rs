//! Paper Figure 6 (bottom-right): distributed training — evaluation
//! return vs *wall-clock time* for 1, 2 and 4 executors (MAD4PG on
//! multi-walker). Expected shape: >1 executor reaches good returns in
//! less wall time, with diminishing returns from 2 -> 4.
//!
//! Every run gets the same wall-clock budget; the curves differ in how
//! fast data is generated (replay's SampleToInsertRatio keeps the
//! trainer honest as executors are added).
//!
//! Scale with MAVA_BENCH_SCALE (default: 60s budget per setting).

use mava::bench;
use mava::config::TrainConfig;

fn cfg(executors: usize, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = "mad4pg".into();
    c.preset = "walker3".into();
    c.num_executors = executors;
    c.max_env_steps = steps;
    c.n_step = 5;
    c.noise_sigma = 0.3;
    c.min_replay = 1_000;
    c.replay_size = 100_000;
    c.samples_per_insert = 32.0;
    c.lr = 1e-3;
    c.eval_every_steps = 2_000;
    c.eval_episodes = 5;
    c.seed = 17;
    c
}

fn main() -> anyhow::Result<()> {
    let budget_s = (60.0 * bench::scale()) as u64;
    // env-step cap high enough that wall clock is the binding budget
    let steps = 10_000_000;
    bench::section(
        "Fig 6 (bottom-right): return vs wall time for 1/2/4 executors",
    );
    let mut results = Vec::new();
    for n in [1usize, 2, 4] {
        let r = bench::figure_run(
            "fig6_distribution",
            &format!("executors_{n}"),
            &cfg(n, steps),
            budget_s,
        )?;
        results.push((n, r));
    }
    println!("\nshape check (same wall budget {budget_s}s):");
    for (n, r) in &results {
        println!(
            "  {n} executor(s): {:>8} env steps, {:>6} train steps, \
             best return {:.2}, time-to(5.0) {:?}",
            r.env_steps,
            r.train_steps,
            r.best_return().unwrap_or(f32::NAN),
            r.time_to(5.0)
        );
    }
    Ok(())
}
