//! Micro-benchmarks of the hot paths: replay insert/sample, environment
//! stepping, PJRT policy-call latency and train-step latency. These are
//! the numbers the §Perf pass in EXPERIMENTS.md tracks.

use std::sync::Arc;

use mava::bench::{report, section, time};
use mava::core::{Actions, HostTensor, StepType};
use mava::replay::{Item, Table, Transition};
use mava::rng::Rng;
use mava::runtime::Engine;
use mava::systems::{self, Executor, SystemKind, Trainer};

fn bench_replay() {
    section("replay table");
    let table = Arc::new(Table::uniform(100_000, 1, 0));
    let tr = Transition {
        obs: vec![0.5; 90],
        state: vec![0.5; 90],
        actions_disc: vec![1; 3],
        actions_cont: vec![],
        rewards: vec![0.1; 3],
        discount: 1.0,
        next_obs: vec![0.5; 90],
        next_state: vec![0.5; 90],
    };
    let t2 = table.clone();
    let trc = tr.clone();
    let s = time(100, 20_000, move || {
        t2.insert(Item::Transition(trc.clone()), 1.0);
    });
    report("replay_insert_smac_transition", &s);

    for _ in 0..10_000 {
        table.insert(Item::Transition(tr.clone()), 1.0);
    }
    let t3 = table.clone();
    let s = time(10, 500, move || {
        let b = t3.sample(128).unwrap();
        std::hint::black_box(b.len());
    });
    report("replay_sample_batch128", &s);
}

fn bench_envs() {
    section("environment stepping (per env step)");
    let mut rng = Rng::new(0);
    for preset in ["matrix2", "switch3", "smac3m", "spread3", "walker3"] {
        let mut env = systems::env_for_preset(preset, 0, None).unwrap();
        let spec = env.spec().clone();
        let mut ts = env.reset();
        let mut r = rng.fork();
        let s = time(100, 20_000, move || {
            if ts.step_type == StepType::Last {
                ts = env.reset();
            }
            let actions = if spec.discrete() {
                Actions::Discrete(
                    (0..spec.n_agents)
                        .map(|i| {
                            if let Some(l) = &ts.legal_actions {
                                let ids: Vec<usize> = (0..spec.n_actions())
                                    .filter(|&k| l[i][k])
                                    .collect();
                                ids[r.below(ids.len())] as i32
                            } else {
                                r.below(spec.n_actions()) as i32
                            }
                        })
                        .collect(),
                )
            } else {
                Actions::Continuous(vec![
                    vec![0.1; spec.n_actions()];
                    spec.n_agents
                ])
            };
            ts = env.step(&actions);
        });
        report(&format!("env_step_{preset}"), &s);
    }
}

fn bench_runtime() {
    section("PJRT runtime (policy call B=1, train step)");
    let Ok(mut engine) = Engine::load("artifacts") else {
        println!("artifacts missing; skipping runtime benches");
        return;
    };
    // policy latency: smac3m madqn (pallas agent_net path)
    let policy = engine.artifact("smac3m_madqn_policy").unwrap();
    let p = engine.read_init("smac3m_madqn_train", "params0").unwrap();
    let params = HostTensor::f32(vec![p.len()], p.clone());
    let obs = HostTensor::f32(vec![1, 3, 30], vec![0.3; 90]);
    let s = time(50, 2_000, || {
        let out = policy.call(&[&params, &obs]).unwrap();
        std::hint::black_box(out[0].as_f32()[0]);
    });
    report("policy_call_smac3m_madqn", &s);

    // full executor act (tensor assembly + call + eps-greedy)
    let mut executor = Executor::new(
        SystemKind::Madqn,
        policy.clone(),
        p.clone(),
        3,
    )
    .unwrap();
    let mut env = systems::env_for_preset("smac3m", 1, None).unwrap();
    let mut ts = env.reset();
    let s = time(50, 2_000, move || {
        if ts.step_type == StepType::Last {
            ts = env.reset();
        }
        let a = executor.select_actions(&ts, 0.1, 0.0).unwrap();
        ts = env.step(&a);
    });
    report("executor_step_smac3m_madqn", &s);

    // train step latency per system family
    for name in [
        "smac3m_madqn_train",
        "smac3m_vdn_train",
        "smac3m_qmix_train",
        "spread3_mad4pg_dec_train",
        "switch3_dial_train",
    ] {
        let art = engine.artifact(name).unwrap();
        let params0 = engine.read_init(name, "params0").unwrap();
        let opt0 = engine.read_init(name, "opt0").unwrap();
        let kind = if name.contains("vdn") {
            SystemKind::Vdn
        } else if name.contains("qmix") {
            SystemKind::Qmix
        } else if name.contains("mad4pg") {
            SystemKind::Mad4pg
        } else if name.contains("dial") {
            SystemKind::Dial
        } else {
            SystemKind::Madqn
        };
        let mut trainer = Trainer::new(
            kind.family(),
            art.clone(),
            params0,
            opt0,
            1e-3,
            0.01,
            7,
        )
        .unwrap();
        trainer.init_target_from_params().unwrap();
        // feed a synthetic table
        let table = Arc::new(Table::uniform(4_096, 1, 0));
        fill_table(&table, kind, &art.spec, trainer.batch_size());
        let s = time(3, 30, move || {
            trainer.step(&table).unwrap();
        });
        report(&format!("train_step_{name}"), &s);
    }
}

fn fill_table(
    table: &Arc<Table>,
    kind: SystemKind,
    spec: &mava::runtime::ArtifactSpec,
    batch: usize,
) {
    let n = spec.meta_usize("n_agents").unwrap();
    let o = spec.meta_usize("obs_dim").unwrap();
    let a = spec.meta_usize("act_dim").unwrap();
    let s_dim = spec.meta_usize("state_dim").unwrap();
    let t_len = spec.meta_usize("seq_len").unwrap();
    let mut rng = Rng::new(3);
    for _ in 0..(batch * 4) {
        if kind.sequences() {
            let seq = mava::replay::Sequence {
                t: t_len,
                obs: (0..(t_len + 1) * n * o).map(|_| rng.f32()).collect(),
                actions: (0..t_len * n).map(|_| rng.below(a) as i32).collect(),
                rewards: vec![0.1; t_len * n],
                discounts: vec![1.0; t_len],
                mask: vec![1.0; t_len],
            };
            table.insert(Item::Sequence(seq), 1.0);
        } else {
            let tr = Transition {
                obs: (0..n * o).map(|_| rng.f32()).collect(),
                state: vec![0.2; s_dim],
                actions_disc: if kind.discrete() {
                    (0..n).map(|_| rng.below(a) as i32).collect()
                } else {
                    vec![]
                },
                actions_cont: if kind.discrete() {
                    vec![]
                } else {
                    vec![0.3; n * a]
                },
                rewards: vec![0.1; n],
                discount: 1.0,
                next_obs: (0..n * o).map(|_| rng.f32()).collect(),
                next_state: vec![0.2; s_dim],
            };
            table.insert(Item::Transition(tr), 1.0);
        }
    }
}

fn main() {
    bench_replay();
    bench_envs();
    bench_runtime();
}
