//! Trainer hot-path throughput: train-steps/s for the host-resident
//! baseline vs the device-resident state loop vs device-resident +
//! prefetched batch assembly (DESIGN.md §8), per system family.
//!
//! The seed trainer re-uploaded `(params [P], target [P], opt [1+2P])`
//! every step and assembled each batch into fresh `Vec`s while the
//! PJRT executable sat idle. The three modes measured here isolate the
//! two fixes: device residency removes the ~5P-float state round-trip,
//! the prefetch thread overlaps sample+assemble with artifact
//! execution. A fourth mode — data-parallel lanes over the
//! `{train}_dp{D}` sharded-gradient artifacts (DESIGN.md §11) — runs
//! when those artifacts are lowered, adding the `devices` axis to the
//! report. Requires `make artifacts`; scale with MAVA_BENCH_SCALE.
//!
//! Besides the grep-able `curve` rows, the run serialises every
//! measured rate as `BENCH_trainer_throughput.json` (the versioned
//! schema of `bench/report.rs` — validate with `mava check-bench`).

use std::sync::Arc;

use mava::bench::report::{
    throughput_report_rows, write_report, ThroughputRow,
};
use mava::bench::{curve_row, report, scale, section, time};
use mava::replay::{Item, Table, Transition};
use mava::rng::Rng;
use mava::runtime::{ArtifactSpec, Engine, Manifest};
use mava::systems::{Family, Trainer};

/// (label, family, train artifact) — one transition-family case per
/// value-based branch of the batch assembler.
const CASES: [(&str, Family, &str); 2] = [
    ("matrix2_madqn", Family::DqnFf, "matrix2_madqn_train"),
    ("matrix2_vdn", Family::ValueDecomp, "matrix2_vdn_train"),
];

fn synthetic_item(family: Family, spec: &ArtifactSpec, rng: &mut Rng) -> Item {
    let n = spec.meta_usize("n_agents").unwrap();
    let o = spec.meta_usize("obs_dim").unwrap();
    let a = spec.meta_usize("act_dim").unwrap();
    let s = spec.meta_usize("state_dim").unwrap();
    let mut t = Transition {
        obs: (0..n * o).map(|_| rng.f32()).collect(),
        actions_disc: (0..n).map(|_| rng.below(a) as i32).collect(),
        rewards: (0..n).map(|_| rng.f32()).collect(),
        discount: 1.0,
        next_obs: (0..n * o).map(|_| rng.f32()).collect(),
        ..Default::default()
    };
    if family == Family::ValueDecomp {
        t.state = (0..s).map(|_| rng.f32()).collect();
        t.next_state = (0..s).map(|_| rng.f32()).collect();
        // team reward: the shared scalar replicated per agent
        t.rewards = vec![rng.f32(); n];
    }
    Item::Transition(t)
}

fn filled_table(family: Family, spec: &ArtifactSpec, batch: usize) -> Arc<Table> {
    let table = Arc::new(Table::uniform(4_096, 1, 17));
    let mut rng = Rng::new(23);
    for _ in 0..batch * 4 {
        table.insert(synthetic_item(family, spec, &mut rng), 1.0);
    }
    table
}

fn bench_case(
    label: &str,
    family: Family,
    train_name: &str,
    series: &mut Vec<ThroughputRow>,
) -> anyhow::Result<()> {
    section(&format!("trainer hot path: {label} ({family:?})"));
    let mut engine = Engine::load("artifacts")?;
    let artifact = engine.artifact(train_name)?;
    let params0 = engine.read_init(train_name, "params0")?;
    let opt0 = engine.read_init(train_name, "opt0")?;
    let batch = artifact.spec.meta_usize("batch")?;
    let table = filled_table(family, &artifact.spec, batch);
    let warmup = 10;
    let iters = (200.0 * scale()) as u64;
    let mut rates = Vec::new();

    // 1. host-resident baseline: full state upload+download per step
    {
        let mut trainer = Trainer::new_host_resident(
            family,
            artifact.clone(),
            params0.clone(),
            opt0.clone(),
            1e-3,
            0.01,
            3,
        )?;
        trainer.init_target_from_params()?;
        let t = table.clone();
        let s = time(warmup, iters, move || {
            trainer.step(&t).unwrap().unwrap();
        });
        report(&format!("train_host_{label}"), &s);
        rates.push(("host", s.per_sec(), 1u64));
    }

    // 2. device-resident: state stays in PjRtBuffers between steps
    {
        let mut trainer = Trainer::new(
            family,
            artifact.clone(),
            params0.clone(),
            opt0.clone(),
            1e-3,
            0.01,
            3,
        )?;
        trainer.init_target_from_params()?;
        let t = table.clone();
        let s = time(warmup, iters, move || {
            trainer.step(&t).unwrap().unwrap();
        });
        report(&format!("train_device_{label}"), &s);
        rates.push(("device", s.per_sec(), 1u64));
    }

    // 3. device-resident + prefetch: batch k+1 assembles while step k
    //    executes
    {
        let mut trainer = Trainer::new(
            family,
            artifact.clone(),
            params0,
            opt0,
            1e-3,
            0.01,
            3,
        )?;
        trainer.init_target_from_params()?;
        let prefetch = trainer.spawn_prefetcher(table.clone(), 2);
        let s = time(warmup, iters, move || {
            let batch = prefetch
                .next_batch()
                .unwrap()
                .expect("prefetcher starved");
            trainer.step_batch(&batch).unwrap();
            prefetch.recycle(batch);
        });
        report(&format!("train_device_prefetch_{label}"), &s);
        rates.push(("device+prefetch", s.per_sec(), 1u64));
    }

    // 4. data-parallel lanes (artifact-gated): sharded gradients over
    //    D lock-step replicas, host all-reduce, shared apply
    //    (DESIGN.md §11). Lowered only for mean-loss systems.
    for d in [2usize, 4] {
        let dp_name = format!("{train_name}_dp{d}");
        let apply_name = format!("{train_name}_apply");
        if engine.manifest.get(&dp_name).is_err()
            || engine.manifest.get(&apply_name).is_err()
        {
            continue;
        }
        let grad = engine.artifact(&dp_name)?;
        let apply = engine.artifact(&apply_name)?;
        let mut trainer = Trainer::new_data_parallel(
            family,
            grad,
            apply,
            params0.clone(),
            opt0.clone(),
            1e-3,
            0.01,
            3,
        )?;
        trainer.init_target_from_params()?;
        let t = table.clone();
        let s = time(warmup, iters, move || {
            trainer.step(&t).unwrap().unwrap();
        });
        report(&format!("train_dp{d}_{label}"), &s);
        rates.push(("dp", s.per_sec(), d as u64));
    }
    table.close();

    let base = rates[0].1;
    println!("\ntrain-step throughput, {label}:");
    for (i, (mode, r, devices)) in rates.iter().enumerate() {
        curve_row("trainer_throughput", label, i as f64, *r);
        let mode_tag = if *mode == "dp" {
            format!("dp{devices}")
        } else {
            mode.replace('+', "_")
        };
        println!(
            "  {mode_tag:<16} {r:>9.0} steps/s   {:>5.2}x vs host",
            r / base
        );
        series.push(
            ThroughputRow::new(
                format!("{label}_{mode_tag}"),
                *r,
                "train_steps/s",
            )
            .with_devices(*devices),
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let Ok(manifest) = Manifest::load("artifacts") else {
        println!("artifacts missing; run `make artifacts` first");
        return Ok(());
    };
    let mut series = Vec::new();
    for (label, family, train_name) in CASES {
        if manifest.get(train_name).is_err() {
            println!("skipping {label}: {train_name} not lowered");
            continue;
        }
        bench_case(label, family, train_name, &mut series)?;
    }
    if !series.is_empty() {
        let json = throughput_report_rows("trainer_throughput", &series);
        let path =
            write_report(std::path::Path::new("."), "trainer_throughput", &json)?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}
