//! Paper Figure 4 (top): switch riddle — MADQN with communication (DIAL)
//! vs plain recurrent MADQN. Expected shape: DIAL's return climbs toward
//! +1 (learned protocol), plain MADQN hovers near the guessing baseline.
//!
//! Scale with MAVA_BENCH_SCALE (default curves: 30k env steps each).

use mava::bench;
use mava::config::TrainConfig;

fn cfg(system: &str, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = system.into();
    c.preset = "switch3".into();
    c.num_executors = 2;
    c.max_env_steps = steps;
    c.min_replay = 200;
    c.replay_size = 20_000;
    c.samples_per_insert = 32.0;
    c.lr = 5e-4;
    c.tau = 0.01;
    c.eps_decay_steps = steps * 2 / 3;
    c.eps_end = 0.02;
    c.eval_every_steps = (steps / 12).max(1);
    c.eval_episodes = 40;
    c.seed = 7;
    c
}

fn main() -> anyhow::Result<()> {
    let steps = (30_000.0 * bench::scale()) as u64;
    bench::section("Fig 4 (top): switch riddle — communication ablation");
    let dial = bench::figure_run("fig4_switch", "dial", &cfg("dial", steps), 600)?;
    let plain =
        bench::figure_run("fig4_switch", "madqn_rec", &cfg("madqn_rec", steps), 600)?;
    println!(
        "\nshape check: DIAL best {:+.3} vs plain MADQN best {:+.3} \
         (paper: comm wins)",
        dial.best_return().unwrap_or(f32::NAN),
        plain.best_return().unwrap_or(f32::NAN)
    );
    Ok(())
}
