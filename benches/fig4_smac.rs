//! Paper Figure 4 (bottom): SMAC 3m — VDN vs independent feedforward
//! MADQN. Expected shape: VDN's decomposed team value learns focus-fire
//! faster / higher than independent learners (QMIX included for
//! completeness; the paper notes their QMIX underperformed too).
//!
//! Scale with MAVA_BENCH_SCALE (default: 40k env steps per system).

use mava::bench;
use mava::config::TrainConfig;

fn cfg(system: &str, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = system.into();
    c.preset = "smac3m".into();
    c.num_executors = 2;
    c.max_env_steps = steps;
    c.min_replay = 1_000;
    c.replay_size = 50_000;
    c.samples_per_insert = 16.0;
    c.lr = 5e-4;
    c.tau = 0.01;
    c.eps_decay_steps = steps / 2;
    c.eps_end = 0.05;
    c.eval_every_steps = (steps / 12).max(1);
    c.eval_episodes = 10;
    c.seed = 11;
    c
}

fn main() -> anyhow::Result<()> {
    let steps = (40_000.0 * bench::scale()) as u64;
    bench::section("Fig 4 (bottom): smac_lite 3m — value decomposition");
    let vdn = bench::figure_run("fig4_smac", "vdn", &cfg("vdn", steps), 900)?;
    let madqn =
        bench::figure_run("fig4_smac", "madqn", &cfg("madqn", steps), 900)?;
    let qmix = bench::figure_run("fig4_smac", "qmix", &cfg("qmix", steps), 900)?;
    println!(
        "\nshape check: VDN best {:.2} vs MADQN best {:.2} (paper: VDN wins); \
         QMIX {:.2} (paper: QMIX underperformed)",
        vdn.best_return().unwrap_or(f32::NAN),
        madqn.best_return().unwrap_or(f32::NAN),
        qmix.best_return().unwrap_or(f32::NAN)
    );
    Ok(())
}
