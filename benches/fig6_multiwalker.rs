//! Paper Figure 6 (mid-right): Multi-Walker — decentralised vs
//! centralised MAD4PG. Expected shape: decentralised solves the task;
//! the centralised critic does *not* help (paper: "centralised training
//! does not seem to help ... consistent with Gupta et al. (2017)").
//!
//! Scale with MAVA_BENCH_SCALE (default: 40k env steps per arch).

use mava::arch::Architecture;
use mava::bench;
use mava::config::TrainConfig;

fn cfg(arch: Architecture, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = "mad4pg".into();
    c.preset = "walker3".into();
    c.arch = arch;
    c.num_executors = 2;
    c.max_env_steps = steps;
    c.n_step = 5;
    c.noise_sigma = 0.3;
    c.min_replay = 1_000;
    c.replay_size = 100_000;
    c.samples_per_insert = 32.0;
    c.lr = 1e-3;
    c.tau = 0.01;
    c.eval_every_steps = (steps / 10).max(1);
    c.eval_episodes = 10;
    c.seed = 13;
    c
}

fn main() -> anyhow::Result<()> {
    let steps = (40_000.0 * bench::scale()) as u64;
    bench::section("Fig 6 (mid-right): multi-walker — dec vs cen MAD4PG");
    let dec = bench::figure_run(
        "fig6_walker",
        "decentralised",
        &cfg(Architecture::Decentralised, steps),
        900,
    )?;
    let cen = bench::figure_run(
        "fig6_walker",
        "centralised",
        &cfg(Architecture::Centralised, steps),
        900,
    )?;
    println!(
        "\nshape check: decentralised best {:.2}, centralised best {:.2} \
         (paper: centralised does not help)",
        dec.best_return().unwrap_or(f32::NAN),
        cen.best_return().unwrap_or(f32::NAN)
    );
    Ok(())
}
