//! Learned communication on the switch riddle (paper Fig 4 top, Block 3):
//! recurrent MADQN (no channel) vs DIAL (differentiable 1-bit channel).
//!
//! In Mava the change is wrapping the architecture with a communication
//! module; in mava-rs it is selecting the `dial` artifacts instead of
//! `madqn_rec` — one line of config.
//!
//! ```bash
//! cargo run --release --example switch_dial -- [env_steps]
//! ```

use anyhow::{Context, Result};
use mava::config::TrainConfig;
use mava::systems::{self, SystemBuilder, SystemSpec};

fn run(system: &str, max_env_steps: u64) -> Result<f32> {
    let mut cfg = TrainConfig::default();
    cfg.preset = "switch3".into();
    cfg.num_executors = 2;
    cfg.max_env_steps = max_env_steps;
    cfg.min_replay = 200;
    cfg.replay_size = 20_000;
    cfg.samples_per_insert = 4.0;
    cfg.lr = 5e-4;
    cfg.tau = 0.01;
    cfg.eps_decay_steps = max_env_steps * 2 / 3;
    cfg.eps_end = 0.02;
    cfg.eval_every_steps = max_env_steps / 10;
    cfg.eval_episodes = 50;
    systems::check_artifacts(&cfg)?;
    // the paper's "communication is one line of config": the two
    // systems differ only in which spec the builder is handed
    let spec = SystemSpec::parse(system)?;
    let result = SystemBuilder::new(spec, &cfg).build()?.run(None)?;
    println!("-- {system} --");
    for e in &result.evals {
        println!(
            "  t={:>6.1}s env={:>7} return={:+.3}",
            e.wall_s, e.env_steps, e.mean_return
        );
    }
    result
        .best_return()
        .with_context(|| format!("{system}: no evaluation completed"))
}

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(60_000);
    let madqn = run("madqn_rec", steps)?;
    let dial = run("dial", steps)?;
    println!("\nswitch riddle (best eval return; optimal = +1):");
    println!("  recurrent MADQN (no comm): {madqn:+.3}");
    println!("  DIAL (learned comm):       {dial:+.3}");
    println!(
        "paper Fig 4 (top): communication is required to beat guessing"
    );
    Ok(())
}
