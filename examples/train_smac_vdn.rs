//! End-to-end driver: distributed VDN on smac_lite 3m (paper Fig 4
//! bottom's winning system) — a real small workload exercising every
//! layer: rust envs + replay + launch graph (L3), the lowered VDN train
//! step (L2) and the pallas agent_net acting path (L1).
//!
//! Logs the evaluation return curve to logs/smac_vdn.csv and stdout; the
//! run recorded in EXPERIMENTS.md used the defaults below.
//!
//! ```bash
//! cargo run --release --example train_smac_vdn -- [env_steps] [executors]
//! ```

use anyhow::Result;
use mava::config::TrainConfig;
use mava::metrics::CsvLogger;
use mava::systems::{self, SystemBuilder, SystemSpec};

fn main() -> Result<()> {
    let max_env_steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(60_000);
    let executors: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);

    let mut cfg = TrainConfig::default();
    cfg.preset = "smac3m".into();
    cfg.num_executors = executors;
    cfg.max_env_steps = max_env_steps;
    cfg.replay_size = 50_000;
    cfg.min_replay = 1_000;
    cfg.samples_per_insert = 8.0;
    cfg.eps_decay_steps = max_env_steps / 2;
    cfg.eps_end = 0.05;
    cfg.lr = 5e-4;
    cfg.tau = 0.01;
    cfg.eval_every_steps = max_env_steps / 20;
    cfg.eval_episodes = 10;
    systems::check_artifacts(&cfg)?;

    println!(
        "VDN on smac_lite 3m: {} env steps, {} executors",
        cfg.max_env_steps, cfg.num_executors
    );
    let result = SystemBuilder::new(SystemSpec::parse("vdn")?, &cfg)
        .build()?
        .run(None)?;
    let log = CsvLogger::create(
        "logs/smac_vdn.csv",
        &["wall_s", "env_steps", "train_steps", "mean_return"],
    )?;
    for e in &result.evals {
        log.log(&[
            e.wall_s,
            e.env_steps as f64,
            e.train_steps as f64,
            e.mean_return as f64,
        ]);
        println!(
            "  t={:>7.1}s env={:>7} train={:>6} return={:>6.2}",
            e.wall_s, e.env_steps, e.train_steps, e.mean_return
        );
    }
    println!(
        "done in {:.1}s: best eval return {:.2} (max shaped return = 20)",
        result.wall_s,
        result.best_return().unwrap_or(f32::NAN)
    );
    Ok(())
}
