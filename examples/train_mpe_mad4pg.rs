//! MAD4PG (distributional MADDPG, n-step) on MPE simple_spread —
//! the continuous-control workload of paper Fig 6 (top-right).
//!
//! ```bash
//! cargo run --release --example train_mpe_mad4pg -- [env_steps] [arch]
//! # arch: dec | cen | net
//! ```

use anyhow::Result;
use mava::arch::Architecture;
use mava::config::TrainConfig;
use mava::systems::{self, SystemBuilder, SystemSpec};

fn main() -> Result<()> {
    let max_env_steps: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(40_000);
    let arch = std::env::args()
        .nth(2)
        .and_then(|s| Architecture::parse(&s))
        .unwrap_or(Architecture::Decentralised);

    let mut cfg = TrainConfig::default();
    cfg.preset = "spread3".into();
    cfg.arch = arch;
    cfg.num_executors = 2;
    cfg.max_env_steps = max_env_steps;
    cfg.n_step = 5;
    cfg.noise_sigma = 0.3;
    cfg.min_replay = 1_000;
    cfg.samples_per_insert = 8.0;
    cfg.lr = 1e-3;
    cfg.eval_every_steps = max_env_steps / 16;
    cfg.eval_episodes = 10;
    systems::check_artifacts(&cfg)?;

    println!("MAD4PG ({arch}) on simple_spread: {max_env_steps} env steps");
    let result = SystemBuilder::new(SystemSpec::parse("mad4pg")?, &cfg)
        .build()?
        .run(None)?;
    for e in &result.evals {
        println!(
            "  t={:>7.1}s env={:>7} return={:>8.2}",
            e.wall_s, e.env_steps, e.mean_return
        );
    }
    println!(
        "best eval return {:.2} (higher = landmarks covered; random ~ -60)",
        result.best_return().unwrap_or(f32::NAN)
    );
    Ok(())
}
