//! Quickstart: the executor-environment interaction loop (paper Block 1)
//! plus inline training — everything on one thread so each piece of the
//! system is visible.
//!
//! Trains independent MADQN on the 2-player climbing matrix game and
//! prints the learning progress. Run with:
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use mava::core::StepType;
use mava::exploration::EpsilonSchedule;
use mava::params::ParameterServer;
use mava::replay::{Table, TransitionAdder};
use mava::runtime::Engine;
use mava::systems::{self, Executor, SystemKind, Trainer};

fn main() -> Result<()> {
    // --- runtime: load AOT artifacts (python never runs here) ---
    let mut engine = Engine::load("artifacts")?;
    println!("PJRT platform: {}", engine.platform());
    let policy = engine.artifact("matrix2_madqn_policy")?;
    let train = engine.artifact("matrix2_madqn_train")?;
    let params0 = engine.read_init("matrix2_madqn_train", "params0")?;
    let opt0 = engine.read_init("matrix2_madqn_train", "opt0")?;

    // --- system pieces: executor, trainer, dataset (paper Fig 2) ---
    let mut env = systems::env_for_preset("matrix2", 0, None)?;
    let table = Arc::new(Table::uniform(10_000, 64, 0));
    let mut adder = TransitionAdder::new(table.clone(), 1, 0.99);
    let mut executor =
        Executor::new(SystemKind::Madqn, policy, params0.clone(), 1)?;
    let mut trainer = Trainer::new(
        SystemKind::Madqn.family(),
        train,
        params0,
        opt0,
        1e-3,
        0.01,
        2,
    )?;
    trainer.init_target_from_params()?;
    let server = ParameterServer::new(trainer.params().to_vec());
    let schedule = EpsilonSchedule::new(1.0, 0.05, 3000);

    // --- Block 1: the executor-environment interaction loop ---
    let mut env_steps = 0u64;
    let mut returns = Vec::new();
    for episode in 0..1200 {
        let mut step = env.reset();
        executor.reset_state();
        adder.observe_first(&step);
        let mut ep_ret = 0.0;
        while step.step_type != StepType::Last {
            // take agent actions and step the environment
            let eps = schedule.value(env_steps);
            let actions = executor.select_actions(&step, eps, 0.0)?;
            step = env.step(&actions);
            // make an observation for each agent
            adder.observe(&actions, &step);
            env_steps += 1;
            ep_ret += step.team_reward() / 2.0;
        }
        returns.push(ep_ret);

        // train once the table can serve batches, then refresh params
        if table.can_sample() {
            for _ in 0..2 {
                trainer.step_and_publish(&table, &server)?;
            }
            let mut buf = Vec::new();
            if let Some(v) = server.sync(executor.params_version, &mut buf) {
                executor.set_params(v, &buf);
            }
        }

        if (episode + 1) % 200 == 0 {
            let recent: f32 =
                returns.iter().rev().take(100).sum::<f32>() / 100.0;
            println!(
                "episode {:>5}  env_steps {:>6}  train_steps {:>5}  \
                 eps {:.2}  return(100) {:>7.2}",
                episode + 1,
                env_steps,
                trainer.stats.steps,
                schedule.value(env_steps),
                recent
            );
        }
    }

    // --- greedy evaluation ---
    let summary = mava::eval::evaluate(&mut executor, env.as_mut(), 20)?;
    println!(
        "greedy eval over {} episodes: mean {:.2} (optimal joint play = 55)",
        summary.episodes, summary.mean_return
    );
    table.close();
    std::thread::sleep(Duration::from_millis(10));
    Ok(())
}
