//! Distributed MADQN — the paper's Block 2 in mava-rs.
//!
//! Builds the multi-node program graph (trainer node, `num_executors`
//! executor nodes, an evaluator, a sharded replay table) through the
//! composable System API and launches it with the local multi-threaded
//! launcher. Compare with the paper:
//!
//! ```python
//! program = madqn.MADQN(
//!     environment_factory=environment_factory,
//!     network_factory=network_factory,
//!     architecture=DecentralisedPolicyActor,
//!     num_executors=2,
//! ).build()
//! launchpad.launch(program, launchpad.LaunchType.LOCAL_MULTI_PROCESSING)
//! ```
//!
//! ```bash
//! cargo run --release --example distributed_madqn -- [num_executors]
//! ```

use anyhow::Result;
use mava::config::TrainConfig;
use mava::systems::{self, SystemBuilder, SystemSpec};

fn main() -> Result<()> {
    let num_executors: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);

    let mut cfg = TrainConfig::default();
    cfg.preset = "matrix2".into();
    cfg.max_env_steps = 8_000;
    cfg.min_replay = 64;
    cfg.eps_decay_steps = 3_000;
    cfg.eval_every_steps = 1_000;
    cfg.eval_episodes = 20;
    systems::check_artifacts(&cfg)?;

    // spec + builder: the mava-rs analogue of the paper's system
    // constructor — the node graph is explicit and inspectable
    let spec = SystemSpec::parse("madqn")?;
    let system = SystemBuilder::new(spec, &cfg)
        .executors(num_executors)
        .build()?;
    println!(
        "launching program graph ({} replay shard(s)): {}",
        system.num_replay_shards(),
        system.node_names().join(" + ")
    );
    let result = system.run(None)?;
    println!(
        "finished: {} env steps / {} train steps / {} episodes in {:.1}s",
        result.env_steps, result.train_steps, result.episodes, result.wall_s
    );
    for e in &result.evals {
        println!(
            "  t={:>6.1}s steps={:>7} return={:+.2}",
            e.wall_s, e.env_steps, e.mean_return
        );
    }
    match result.best_return() {
        Some(best) => println!("best eval return: {best:+.2}"),
        None => println!("no evaluation completed (run too short)"),
    }
    Ok(())
}
