//! Distributed MADQN — the paper's Block 2 in mava-rs.
//!
//! Builds the multi-node program graph (replay node, trainer node,
//! `num_executors` executor nodes, an evaluator) and launches it with the
//! local multi-threaded launcher. Compare with the paper:
//!
//! ```python
//! program = madqn.MADQN(
//!     environment_factory=environment_factory,
//!     network_factory=network_factory,
//!     architecture=DecentralisedPolicyActor,
//!     num_executors=2,
//! ).build()
//! launchpad.launch(program, launchpad.LaunchType.LOCAL_MULTI_PROCESSING)
//! ```
//!
//! ```bash
//! cargo run --release --example distributed_madqn -- [num_executors]
//! ```

use anyhow::Result;
use mava::config::TrainConfig;
use mava::systems;

fn main() -> Result<()> {
    let num_executors: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);

    let mut cfg = TrainConfig::default();
    cfg.system = "madqn".into();
    cfg.preset = "matrix2".into();
    cfg.num_executors = num_executors;
    cfg.max_env_steps = 8_000;
    cfg.min_replay = 64;
    cfg.eps_decay_steps = 3_000;
    cfg.eval_every_steps = 1_000;
    cfg.eval_episodes = 20;
    systems::check_artifacts(&cfg)?;

    println!(
        "launching program graph: 1 replay + 1 trainer + {} executors + 1 evaluator",
        cfg.num_executors
    );
    let result = systems::train(&cfg, None)?;
    println!(
        "finished: {} env steps / {} train steps / {} episodes in {:.1}s",
        result.env_steps, result.train_steps, result.episodes, result.wall_s
    );
    for e in &result.evals {
        println!(
            "  t={:>6.1}s steps={:>7} return={:+.2}",
            e.wall_s, e.env_steps, e.mean_return
        );
    }
    println!("best eval return: {:+.2}", result.best_return());
    Ok(())
}
